"""Elastic autoscaling for `ServingCluster`: per-label load tracking,
spawn/retire/rebalance decisions, and intent-pinned scaling bounds.

The paper's online-reconfiguration machinery (PREPARE-phase AOT compile +
blocking swap, <50 ms downtime) only pays off when the system can *add and
remove* capacity per workload class, not just reconfigure one resident
engine. This module closes that loop (LLM-Mesh-style elastic sharing):

    LoadTracker     per-label EWMA arrival rate + queue depth, fed from the
                    cluster's demand counters and `metrics()` aggregation;
    ElasticPolicy   hysteresis policy turning tracked load + scaling bounds
                    into `ScaleDecision`s (spawn a dedicated engine for a
                    hot label, retire a drained idle one, or REBALANCE an
                    idle engine onto the hot label when a resize beats a
                    cold spawn);
    Autoscaler      executes decisions through the cluster's elastic
                    lifecycle (`spawn_engine` / `retire_engine` /
                    `rebalance` — all built on pause/drain/swap/resume, so
                    scaling never JITs on the serving path) and accepts
                    intent-compiled scaling bounds via `apply_policy`, i.e.
                    ``Orchestrator.submit(text, apply_to=autoscaler)``.

The control loop is tick-driven and uses virtual time (``dt``), so tests
and benchmarks are deterministic:

    scaler = Autoscaler(cluster, factory)
    while serving:
        ... submit requests, cluster.step() ...
        scaler.tick()          # observe -> decide -> scale

See docs/architecture.md (autoscaler loop) and docs/reconfiguration.md
(worked example) for the full story.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.obs import events as obs_events
from repro.serving.clock import SYSTEM_CLOCK
from repro.serving.cluster import DowntimeReport, ServingCluster
from repro.serving.engine import ServingEngine
from repro.serving.prepare import FAILED, SWAPPED, PrepareTicket
from repro.sharding.plan import (
    ShardingPlan,
    merge_restrictions,
    plan_satisfies,
)

# (min_engines, max_engines); max None == unbounded
Bounds = Tuple[int, Optional[int]]


@dataclasses.dataclass(frozen=True)
class ScaleDecision:
    """One autoscaling action, as emitted by `ElasticPolicy.decide`.

    Attributes:
        kind: ``"spawn"`` | ``"retire"`` | ``"rebalance"``.
        label: the ``data-type`` label value the decision serves.
        engine: target engine name (the engine to retire or retarget;
            empty for a spawn — the `Autoscaler` names spawned engines).
        reason: human-readable justification (telemetry / benchmark CSV).
        mode: retirement mode — ``"drain"`` (serve out the queue first)
            or ``"migrate"`` (live-migrate in-flight work to peers and
            reap immediately). Ignored for spawn/rebalance.
    """

    kind: str
    label: str
    engine: str = ""
    reason: str = ""
    mode: str = "drain"


class LoadTracker:
    """Per-label EWMA arrival rate and queue depth.

    Fed from `ServingCluster.arrivals()` (cumulative per-label submission
    counts, including fail-closed rejections — rejected demand is still
    demand) and `ServingCluster.queue_depth_by_label()`. Labels with no
    traffic are zero-filled by the cluster's per-label views, so every
    known label is always observable.

    Args:
        alpha: EWMA smoothing factor in (0, 1]; 1.0 == no smoothing.
    """

    def __init__(self, alpha: float = 0.5):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = alpha
        self._rate: Dict[str, float] = {}
        self._depth: Dict[str, float] = {}
        self._depth_tokens: Dict[str, float] = {}
        self._last_arrivals: Dict[str, int] = {}

    def observe(self, cluster: ServingCluster, dt: float = 1.0) -> None:
        """Fold one tick of cluster state into the EWMAs.

        Args:
            cluster: the cluster to sample.
            dt: virtual seconds since the previous observation (rates are
                per-``dt`` unit; keep it constant for deterministic runs).

        Raises:
            ValueError: if ``dt`` is not positive.
        """
        if dt <= 0:
            raise ValueError(f"dt must be positive, got {dt}")
        arrivals = cluster.arrivals()
        depths = cluster.queue_depth_by_label(extra_labels=self.labels())
        tok_depths = cluster.queued_tokens_by_label(
            extra_labels=self.labels())
        for label in set(arrivals) | set(depths) | set(self._rate):
            inst_rate = (arrivals.get(label, 0)
                         - self._last_arrivals.get(label, 0)) / dt
            self._rate[label] = (self._rate.get(label, 0.0)
                                 + self.alpha
                                 * (inst_rate - self._rate.get(label, 0.0)))
            d = float(depths.get(label, 0))
            self._depth[label] = (self._depth.get(label, 0.0)
                                  + self.alpha
                                  * (d - self._depth.get(label, 0.0)))
            t = float(tok_depths.get(label, 0))
            self._depth_tokens[label] = (
                self._depth_tokens.get(label, 0.0)
                + self.alpha * (t - self._depth_tokens.get(label, 0.0)))
        self._last_arrivals = arrivals

    def rate(self, label: str) -> float:
        """EWMA arrival rate (requests per ``dt`` unit) for ``label``;
        0.0 for labels never observed."""
        return self._rate.get(label, 0.0)

    def depth(self, label: str) -> float:
        """EWMA queued+resident request count for ``label``; 0.0 for
        labels never observed."""
        return self._depth.get(label, 0.0)

    def depth_tokens(self, label: str) -> float:
        """EWMA outstanding KV-token demand for ``label`` (the
        token-granular sibling of `depth` — what a paged pool's
        admission actually meters); 0.0 for labels never observed."""
        return self._depth_tokens.get(label, 0.0)

    def labels(self) -> List[str]:
        """All labels ever observed (including the ``"*"`` unlabeled
        bucket), sorted."""
        return sorted(set(self._rate) | set(self._depth))


class ElasticPolicy:
    """Hysteresis scaling policy: sustained overload spawns, sustained
    idleness retires, and a cooldown after every action prevents flapping.

    Decision rules, per label (the ``"*"`` unlabeled bucket is exempt —
    unlabeled traffic may land on any engine, so it never owns capacity):

      * below ``min``: spawn immediately (a pinned floor is mandatory,
        bypassing the sustain window) — but if the previous floor spawn
        added a dedicated engine without raising the eligible count, the
        floor is blocked by a constraint conflict that more spawns cannot
        fix, and the policy stops rather than accumulate never-eligible
        engines;
      * hot — EWMA queue depth per serving engine > ``spawn_depth`` (any
        demand at all counts as hot when NO engine serves the label) — for
        ``sustain`` consecutive ticks, and under ``max``: spawn, or
        rebalance an idle donor engine dedicated to a cold label when one
        exists above that label's floor (a resize beats a cold spawn);
      * cold — EWMA rate <= ``retire_rate`` and depth <= ``retire_depth``
        — for ``sustain`` ticks, and above ``min``: retire one engine
        DEDICATED to the label (never a shared engine) whose load is
        already zero — retirement strictly follows drain. With
        ``prefer_migrate`` and no drained candidate, a dedicated engine
        whose in-flight work FITS its peers' free slots is retired in
        ``"migrate"`` mode instead: its requests are live-migrated and
        the engine reaps immediately, bounding scale-down latency by the
        per-request migration pause rather than the longest decode;
      * after any action on a label (including the donor of a rebalance):
        no further action on it for ``cooldown`` ticks.

    The policy is stateful (per-label streaks and cooldowns); use one
    instance per control loop.
    """

    def __init__(self, *, spawn_depth: float = 4.0, retire_rate: float = 0.25,
                 retire_depth: float = 0.5, sustain: int = 2,
                 cooldown: int = 3, default_bounds: Bounds = (0, 4),
                 prefer_rebalance: bool = True,
                 prefer_migrate: bool = False):
        self.spawn_depth = spawn_depth
        self.retire_rate = retire_rate
        self.retire_depth = retire_depth
        self.sustain = max(1, sustain)
        self.cooldown = cooldown
        self.default_bounds = default_bounds
        self.prefer_rebalance = prefer_rebalance
        # opt-in fast scale-down (live migration); the default preserves
        # strict retire-follows-drain semantics
        self.prefer_migrate = prefer_migrate
        self._hot: Dict[str, int] = {}       # label -> consecutive hot ticks
        self._cold: Dict[str, int] = {}      # label -> consecutive cold ticks
        self._cooldown: Dict[str, int] = {}  # label -> ticks remaining
        # label -> (eligible n, dedicated total) snapshot at the last
        # floor-enforcement spawn: if the spawn added a dedicated engine
        # but n did not grow, the floor is blocked by a constraint
        # conflict and further spawns cannot help
        self._floor_probe: Dict[str, Tuple[int, int]] = {}

    def clear_cooldown(self, label: str) -> None:
        """Watchtower hook: drop ``label``'s post-action cooldown (and
        its sustain counters' inertia) so the next `decide` may act
        immediately. The decision rules themselves are unchanged —
        clearing hysteresis never forces an action, it only stops the
        policy from sitting out a confirmed incident."""
        self._cooldown.pop(label, None)

    # -- helpers -------------------------------------------------------
    def _dedicated_idle(self, cluster: ServingCluster, label: str,
                        claimed: set) -> List[str]:
        """Engines dedicated to ``label`` (engine label == label) with no
        queued or resident work and not already claimed by another
        decision this tick — the only legal retire/donor targets."""
        out = []
        for name in cluster.engines_for_label(label):
            eng = cluster.engine(name)
            if (name not in claimed
                    and eng.labels.get(cluster.ROUTE_KEY) == label
                    and eng.load == 0):
                out.append(name)
        return out

    def _dedicated_migratable(self, cluster: ServingCluster, label: str,
                              claimed: set) -> Optional[str]:
        """The least-loaded engine dedicated to ``label`` whose in-flight
        work fits into its peers' free capacity — a migrate-mode
        retirement can relocate everything and reap it immediately.
        Capacity is checked token-granularly as well as by decode lane:
        a paged peer admits by pages, so its free KV tokens (not its
        lane count) decide whether the resident extents fit. ``None``
        when no peer exists or capacity doesn't fit (fall back to
        waiting for a drain)."""
        names = cluster.engines_for_label(label)
        dedicated = [
            n for n in names
            if n not in claimed
            and cluster.engine(n).labels.get(cluster.ROUTE_KEY) == label]
        for name in sorted(dedicated, key=lambda n: cluster.engine(n).load):
            eng = cluster.engine(name)
            resident = sum(r is not None for r in eng.slot_req)
            resident_tok = sum(
                min(len(r.prompt) + r.max_new_tokens, eng.s_max)
                for r in eng.slot_req if r is not None)
            # only RUNNING peers count: the relocation refuses to strand
            # a decoding request on a paused engine
            peers = [p for p in names if p != name and p not in claimed
                     and not cluster.engine(p).paused]
            peers_free = sum(cluster.engine(p).free_slots for p in peers)
            peers_tok = sum(cluster.engine(p).free_tokens for p in peers)
            if peers and peers_free >= resident \
                    and peers_tok >= resident_tok:
                return name
        return None

    def _dedicated_total(self, cluster: ServingCluster, label: str) -> int:
        """Engines dedicated to ``label`` regardless of routing
        eligibility — the floor-enforcement backstop: capacity that exists
        but fails the route constraint means spawning MORE engines cannot
        help (a constraint conflict, not a capacity shortfall)."""
        return sum(
            1 for name in cluster.engines()
            if cluster.engine(name).labels.get(cluster.ROUTE_KEY) == label
            and name not in cluster.draining())

    def _donor(self, tracker: LoadTracker, cluster: ServingCluster,
               hot_label: str, bounds: Dict[str, Bounds],
               claimed: set) -> Optional[str]:
        """An idle engine dedicated to a cold label, above that label's
        floor, that can be retargeted at ``hot_label`` — and whose plan,
        once merged with the hot label's route constraint, would actually
        satisfy it (a donor whose device pins conflict with the
        constraint would come out of the swap unroutable for every
        label: worse than a cold spawn, not better)."""
        required = cluster.required_for({cluster.ROUTE_KEY: hot_label})
        for other in tracker.labels():
            if other in (hot_label, "*"):
                continue
            if (tracker.rate(other) > self.retire_rate
                    or tracker.depth(other) > self.retire_depth):
                continue
            lo, _ = bounds.get(other, self.default_bounds)
            if len(cluster.engines_for_label(other)) <= lo:
                continue
            for name in self._dedicated_idle(cluster, other, claimed):
                base = cluster.engine(name).plan
                if required is None or plan_satisfies(
                        merge_restrictions(base, required), required):
                    return name
        return None

    # -- the decision function -----------------------------------------
    def decide(self, tracker: LoadTracker, cluster: ServingCluster,
               bounds: Dict[str, Bounds]) -> List[ScaleDecision]:
        """Turn tracked load into scale decisions (at most one per label
        per tick). Pure decision logic — execution is the `Autoscaler`'s
        job.

        TICKET-AWARE: capacity whose background PREPARE is still in
        flight (`ServingCluster.pending_spawn_labels`) counts toward a
        label's engine count, so bursty load during a slow compile sizes
        further scale-ups against what is already being built instead of
        re-requesting it every tick.

        Args:
            tracker: the observed per-label load.
            cluster: the live cluster (capacity + idleness queries only).
            bounds: per-label (min, max) engine counts; labels absent fall
                back to ``default_bounds``.

        Returns:
            The decisions for this tick, in label order.
        """
        decisions: List[ScaleDecision] = []
        claimed: set = set()          # engines already targeted this tick
        pending = cluster.pending_spawn_labels()
        labels = [v for v in set(tracker.labels()) | set(bounds) if v != "*"]
        for label in sorted(labels):
            lo, hi = bounds.get(label, self.default_bounds)
            n = len(cluster.engines_for_label(label)) \
                + pending.get(label, 0)

            # a pinned floor is mandatory — enforce before anything else.
            # Backstop: if the PREVIOUS floor spawn added a dedicated
            # engine without raising n, spawns are not becoming eligible
            # (constraint conflict) and repeating them cannot help — stop
            # until eligibility actually changes.
            if n < lo:
                dedicated = self._dedicated_total(cluster, label)
                probe = self._floor_probe.get(label)
                blocked = (probe is not None and n <= probe[0]
                           and dedicated > probe[1])
                if not blocked:
                    decisions.append(ScaleDecision(
                        "spawn", label,
                        reason=f"below floor: {n} < min {lo}"))
                    self._floor_probe[label] = (n, dedicated)
                    self._cooldown[label] = self.cooldown
                    self._hot[label] = self._cold[label] = 0
                continue
            self._floor_probe.pop(label, None)

            depth, rate = tracker.depth(label), tracker.rate(label)
            # with no engine at all, any real demand is hot (EWMAs decay
            # geometrically and never reach exactly 0 — compare against
            # the retire thresholds, not strict positivity)
            hot = (depth > self.retire_depth or rate > self.retire_rate) \
                if n == 0 else (depth / n > self.spawn_depth)
            cold = rate <= self.retire_rate and depth <= self.retire_depth
            self._hot[label] = self._hot.get(label, 0) + 1 if hot else 0
            self._cold[label] = self._cold.get(label, 0) + 1 if cold else 0

            if self._cooldown.get(label, 0) > 0:
                self._cooldown[label] -= 1
                continue

            if self._hot[label] >= self.sustain and (hi is None or n < hi):
                donor = self._donor(tracker, cluster, label, bounds,
                                    claimed) if self.prefer_rebalance \
                    else None
                if donor is not None:
                    decisions.append(ScaleDecision(
                        "rebalance", label, engine=donor,
                        reason=f"hot (depth/engine {depth/max(n,1):.1f} > "
                               f"{self.spawn_depth}); idle donor beats "
                               "cold spawn"))
                    claimed.add(donor)
                    donor_label = cluster.engine(donor).labels.get(
                        cluster.ROUTE_KEY, "*")
                    self._cooldown[donor_label] = self.cooldown
                else:
                    decisions.append(ScaleDecision(
                        "spawn", label,
                        reason=f"hot for {self._hot[label]} ticks "
                               f"(depth/engine {depth/max(n,1):.1f} > "
                               f"{self.spawn_depth})"))
                self._cooldown[label] = self.cooldown
                self._hot[label] = 0
            elif self._cold[label] >= self.sustain and n > lo:
                idle = self._dedicated_idle(cluster, label, claimed)
                if idle:               # retire strictly follows drain
                    decisions.append(ScaleDecision(
                        "retire", label, engine=idle[0],
                        reason=f"cold for {self._cold[label]} ticks "
                               f"(rate {rate:.2f} <= {self.retire_rate})"))
                    claimed.add(idle[0])
                    self._cooldown[label] = self.cooldown
                    self._cold[label] = 0
                elif self.prefer_migrate:
                    cand = self._dedicated_migratable(cluster, label,
                                                      claimed)
                    if cand is not None:   # relocate-and-reap immediately
                        decisions.append(ScaleDecision(
                            "retire", label, engine=cand, mode="migrate",
                            reason=f"cold for {self._cold[label]} ticks; "
                                   "peers have free slots — migrate "
                                   "in-flight work instead of draining"))
                        claimed.add(cand)
                        self._cooldown[label] = self.cooldown
                        self._cold[label] = 0
        return decisions


class Autoscaler:
    """Drives a `ServingCluster`'s elastic lifecycle from per-label load.

    Args:
        cluster: the cluster to scale.
        factory: ``factory(label) -> ServingEngine`` building a fresh
            engine for a label (model/params/slot sizing is the caller's
            policy). The autoscaler installs the label and a route-
            constraint-satisfying plan itself.
        policy: decision policy (default `ElasticPolicy()`).
        tracker: load tracker (default `LoadTracker()`).
        bounds: initial per-label (min, max) engine counts; extended by
            `set_bounds` or intent application (`apply_policy`).
        async_spawn: issue spawns through `spawn_engine_async`, so a
            scale-up's AOT compile never stalls the tick loop — the new
            engine joins the pool at a later step boundary. While a
            label's spawn is in flight, further spawn decisions for it
            are suppressed (capacity that is already being built is not
            re-requested every tick). Retire/rebalance stay synchronous:
            they move no compile work.
        planner: a `repro.planner.WorkloadPlanner` — PLANNER MODE: the
            threshold `policy` is replaced by cost-model-driven
            configuration planning (forecast -> search -> PlanAction
            diff), executed through the same machinery so ``events`` /
            ``trajectory`` / ``failures`` record uniformly. The
            tracker/bounds plumbing (and intent application via
            `apply_policy`) is shared; ``policy`` is ignored while a
            planner is installed.
        clock: the time source tick timestamps are read from (default
            the real `repro.serving.clock.SYSTEM_CLOCK`). The decision
            path itself performs NO clock reads — sustain/cooldown
            hysteresis is counted in ticks, each worth ``dt`` virtual
            seconds — so injecting a simulated `FakeClock` makes the
            whole control loop wall-clock-free: a 10^6-request replay's
            scaling decisions depend only on the trace, never on how
            fast the host happens to run it.

    Attributes:
        events: ``[(ScaleDecision, DowntimeReport), ...]`` for every
            executed scale event, in order. With ``async_spawn``, a
            spawn's entry is appended at the tick that observes its
            commit.
        trajectory: per-tick ``{label: engine count, "total": n}``
            snapshots (the benchmark's engine-count trajectory).
        tick_times: per-tick timestamps on the injected ``clock``
            (parallel to ``trajectory``).
    """

    def __init__(self, cluster: ServingCluster,
                 factory: Callable[[str], ServingEngine], *,
                 policy: Optional[ElasticPolicy] = None,
                 tracker: Optional[LoadTracker] = None,
                 bounds: Optional[Dict[str, Bounds]] = None,
                 async_spawn: bool = False,
                 planner: Optional[object] = None,
                 clock=None):
        self.cluster = cluster
        self.factory = factory
        self.policy = policy or ElasticPolicy()
        self.tracker = tracker or LoadTracker()
        self.bounds: Dict[str, Bounds] = dict(bounds or {})
        self.async_spawn = async_spawn
        self.planner = planner
        self.clock = clock if clock is not None else SYSTEM_CLOCK
        self.tick_times: List[float] = []
        self.events: List[Tuple[ScaleDecision, DowntimeReport]] = []
        # async spawns whose background PREPARE failed: (decision, error)
        # — surfaced here instead of silently vanishing from the loop
        self.failures: List[Tuple[ScaleDecision, BaseException]] = []
        self.trajectory: List[Dict[str, int]] = []
        # spawn decisions whose background PREPARE is still in flight
        self._pending: List[Tuple[ScaleDecision, PrepareTicket]] = []
        # label -> ticks to hold off respawning after a FAILED async
        # spawn (a deterministic PREPARE failure must not become one
        # expensive failing background compile per tick, forever)
        self._spawn_backoff: Dict[str, int] = {}
        self._spawn_seq = 0

    # ------------------------------------------------------------------
    def set_bounds(self, label: str, lo: int, hi: Optional[int] = None
                   ) -> None:
        """Pin scaling bounds for a label: keep at least ``lo`` and at
        most ``hi`` (None == unbounded) engines able to serve it.

        Raises:
            ValueError: if ``lo`` is negative or exceeds ``hi``.
        """
        if lo < 0 or (hi is not None and lo > hi):
            raise ValueError(f"invalid bounds for {label!r}: ({lo}, {hi})")
        self.bounds[label] = (lo, hi)

    def apply_policy(self, policy, components: Sequence = (), *,
                     async_prepare: bool = False
                     ) -> Dict[str, DowntimeReport]:
        """Intent hook: `Orchestrator.submit(text, apply_to=autoscaler)`.

        Installs the compiled policy's per-label scaling bounds
        (``policy.scale_bounds``), then delegates route-constraint
        installation + engine reconfiguration to the underlying cluster's
        `apply_policy` (``async_prepare`` rides the concurrent-PREPARE
        path there). Bounds take effect on the next `tick()` — a pinned
        floor spawns immediately there.

        Returns:
            {engine name: DowntimeReport} for engines the cluster swapped
            (`PrepareTicket`s when ``async_prepare``).
        """
        for label, (lo, hi) in getattr(policy, "scale_bounds", {}).items():
            self.set_bounds(label, lo, hi)
        if self.planner is not None:
            # planner mode: Φ_L service-level targets + bounds flow into
            # the planner objective; route-constraint installation and
            # engine reconfiguration delegate to the cluster through it
            return self.planner.apply_policy(policy, components=components,
                                             async_prepare=async_prepare)
        return self.cluster.apply_policy(policy, components=components,
                                         async_prepare=async_prepare)

    # ------------------------------------------------------------------
    def _plan_for(self, label: str, base: ShardingPlan) -> ShardingPlan:
        """Merge the label's route constraint (if any — data-type AND
        matching selector constraints) into ``base`` so a spawned/
        rebalanced engine is immediately routing-eligible (same
        fail-closed merge semantics as cluster `apply_policy` swaps)."""
        required = self.cluster.required_for(
            {self.cluster.ROUTE_KEY: label})
        if required is None:
            return base
        return merge_restrictions(base, required)

    def _spawn_name(self, label: str) -> str:
        """A fresh engine name: skip names already live in the cluster OR
        reserved by an in-flight async spawn (a previous scaler instance
        or a manual registration may own them)."""
        taken = set(self.cluster.engines()) | set(self.cluster.pending_spawns())
        name = f"{label}-as{self._spawn_seq}"
        while name in taken:
            self._spawn_seq += 1
            name = f"{label}-as{self._spawn_seq}"
        self._spawn_seq += 1
        return name

    def _execute(self, d: ScaleDecision) -> DowntimeReport:
        if d.kind == "spawn":
            engine = self.factory(d.label)
            report = self.cluster.spawn_engine(
                self._spawn_name(d.label), engine,
                plan=self._plan_for(d.label, engine.plan),
                labels={self.cluster.ROUTE_KEY: d.label},
                prefill_lengths=self.cluster.label_prompt_lengths(d.label))
        elif d.kind == "retire":
            report = self.cluster.retire_engine(d.engine, mode=d.mode)
        elif d.kind == "rebalance":
            base = self.cluster.engine(d.engine).plan
            report = self.cluster.rebalance(
                d.engine, self._plan_for(d.label, base),
                labels={self.cluster.ROUTE_KEY: d.label},
                prefill_lengths=self.cluster.label_prompt_lengths(d.label))
        else:
            raise ValueError(f"unknown decision kind {d.kind!r}")
        return report

    def _spawn_async(self, d: ScaleDecision) -> PrepareTicket:
        """Issue one spawn through the concurrent-PREPARE path: the AOT
        compile runs on the `PrepareWorker`; the tick loop never waits."""
        engine = self.factory(d.label)
        return self.cluster.spawn_engine_async(
            self._spawn_name(d.label), engine,
            plan=self._plan_for(d.label, engine.plan),
            labels={self.cluster.ROUTE_KEY: d.label},
            prefill_lengths=self.cluster.label_prompt_lengths(d.label))

    def _reap_pending(self) -> None:
        """Fold committed async spawns into ``events``; a FAILED spawn is
        recorded in ``failures`` and its label backs off for ``cooldown``
        ticks (cancelled tickets just drop — no capacity was promised)."""
        if not self._pending:
            return
        self.cluster.commit_ready()        # tick == a safe step boundary
        keep: List[Tuple[ScaleDecision, PrepareTicket]] = []
        for d, t in self._pending:
            if t.state == SWAPPED:
                self.events.append((d, t.report))
            elif t.state == FAILED:
                self.failures.append((d, t.error))
                self._spawn_backoff[d.label] = max(self.policy.cooldown, 1)
            elif not t.done():
                keep.append((d, t))
        self._pending = keep

    def pending_spawns(self) -> List[ScaleDecision]:
        """Spawn decisions whose background PREPARE is still in flight."""
        return [d for d, t in self._pending if not t.done()]

    def tick(self, dt: float = 1.0) -> List[ScaleDecision]:
        """One control-loop iteration: observe load, decide, execute.

        Args:
            dt: virtual seconds since the last tick (see
                `LoadTracker.observe`).

        Returns:
            The decisions executed this tick (empty most ticks). Every
            executed decision's `DowntimeReport` is appended to
            ``self.events`` (for async spawns: at the tick observing the
            commit); a per-label engine-count snapshot is appended to
            ``self.trajectory``.
        """
        self.tick_times.append(self.clock.time())
        for label in list(self._spawn_backoff):
            self._spawn_backoff[label] -= 1
            if self._spawn_backoff[label] <= 0:
                del self._spawn_backoff[label]
        self._reap_pending()
        self.tracker.observe(self.cluster, dt)
        if self.planner is not None:
            executed = self._tick_planner()
        else:
            decisions = self.policy.decide(self.tracker, self.cluster,
                                           self.bounds)
            inflight = {d.label for d, t in self._pending if not t.done()}
            inflight |= set(self._spawn_backoff)
            executed = []
            for d in decisions:
                if d.kind == "spawn" and d.label in inflight:
                    continue  # that capacity is already being prepared
                if d.kind == "spawn" and self.async_spawn:
                    self._pending.append((d, self._spawn_async(d)))
                    inflight.add(d.label)
                else:
                    self.events.append((d, self._execute(d)))
                executed.append(d)
        snap = {label: len(self.cluster.engines_for_label(label))
                for label in self.tracker.labels() if label != "*"}
        snap["total"] = len(self.cluster.engines())
        self.trajectory.append(snap)
        rec = obs_events.RECORDER
        if rec is not None:
            for d in executed:
                rec.emit("scale.decision", engine=d.engine, label=d.label,
                         action=d.kind, mode=d.mode, reason=d.reason,
                         mode_planner=self.planner is not None)
        return executed

    def mandatory_fix(self, label: str, reason: str = "") -> None:
        """Watchtower hook: a fired alert clears ``label``'s scaling
        hysteresis — the policy cooldown and any spawn backoff — so the
        next tick may react at once instead of waiting out timers meant
        for steady-state flap damping. In planner mode the planner's own
        dwell gates are cleared too (`WorkloadPlanner.mandatory_fix`)."""
        if hasattr(self.policy, "clear_cooldown"):
            self.policy.clear_cooldown(label)
        self._spawn_backoff.pop(label, None)
        if self.planner is not None:
            self.planner.mandatory_fix(label, reason=reason)
        rec = obs_events.RECORDER
        if rec is not None:
            rec.emit("scale.mandatory_fix", label=label, reason=reason)

    def _tick_planner(self) -> List[ScaleDecision]:
        """One planner-mode iteration: forecast -> plan -> execute, with
        the executed `PlanAction`s recorded as `ScaleDecision`-shaped
        events (async tickets fold into ``events`` at the tick observing
        their commit, exactly like threshold-mode spawns)."""
        demand = self.planner.forecast(self.tracker)
        backoff = set(self._spawn_backoff)
        actions = [a for a in self.planner.plan(demand, bounds=self.bounds)
                   if not (a.kind == "spawn" and a.label in backoff)]
        executed: List[ScaleDecision] = []
        for a, res in self.planner.execute(actions,
                                           async_spawn=self.async_spawn):
            d = ScaleDecision(a.kind, a.label, engine=a.engine,
                              reason=a.reason, mode=a.mode)
            if isinstance(res, PrepareTicket):
                if not res.done():
                    self._pending.append((d, res))
                elif res.state == SWAPPED:
                    self.events.append((d, res.report))
                elif res.state == FAILED:
                    self.failures.append((d, res.error))
                    self._spawn_backoff[a.label] = max(
                        getattr(self.policy, "cooldown", 1), 1)
            elif res is not None:          # sync DowntimeReport
                self.events.append((d, res))
            executed.append(d)
        return executed
