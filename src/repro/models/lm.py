"""Decoder-only LM assembly (dense / MoE / SSM / hybrid families).

Layers are scan-stacked (leading L dim on every layer param / cache leaf)
so the traced graph contains ONE layer body regardless of depth — essential
for fast lowering of 96-layer configs and for clean pjit partitioning.

Hybrid (Jamba) models scan over *periods*: one period = `hybrid_period`
explicit sub-layers (attention at `hybrid_attn_offsets`, Mamba elsewhere;
MoE per the MoEConfig cadence).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mlp as ffn
from repro.models import ssm as ssd
from repro.models.common import (
    apply_norm,
    embed_init,
    init_norm,
    padded_vocab,
    param_dtype_of,
    vocab_mask,
)
from repro.sharding.ctx import constrain

PyTree = Any


# ---------------------------------------------------------------------------
# per-position layer kinds
# ---------------------------------------------------------------------------


def layer_kinds(cfg: ModelConfig) -> Tuple[Tuple[str, str], ...]:
    """(mixer_kind, ffn_kind) for each in-period position (or the single
    repeated layer for homogeneous models)."""
    period = cfg.hybrid_period or 1
    kinds = []
    for off in range(period):
        if cfg.family == "ssm":
            mixer = "ssm"
        elif cfg.hybrid_period:
            mixer = "attn" if off in cfg.hybrid_attn_offsets else "ssm"
        else:
            mixer = "mla" if cfg.attn_type == "mla" else "attn"
        if cfg.family == "ssm":
            f = "none"
        elif cfg.moe is not None and (off % cfg.moe.every_k_layers == cfg.moe.offset):
            f = "moe"
        else:
            f = "mlp"
        kinds.append((mixer, f))
    return tuple(kinds)


def n_scan_steps(cfg: ModelConfig) -> int:
    period = cfg.hybrid_period or 1
    assert cfg.num_layers % period == 0, (cfg.num_layers, period)
    return cfg.num_layers // period


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_sublayer(cfg: ModelConfig, key: jax.Array, mixer: str, f: str) -> dict:
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"mixer_norm": init_norm(cfg, cfg.d_model)}
    if mixer == "attn":
        p["mixer"] = attn.init_gqa(cfg, ks[0])
    elif mixer == "mla":
        p["mixer"] = attn.init_mla(cfg, ks[0])
    else:
        p["mixer"] = ssd.init_ssm(cfg, ks[0])
    if f != "none":
        p["ffn_norm"] = init_norm(cfg, cfg.d_model)
        p["ffn"] = ffn.init_moe(cfg, ks[1]) if f == "moe" else ffn.init_mlp(cfg, ks[1])
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> PyTree:
    pd = param_dtype_of(cfg)
    kinds = layer_kinds(cfg)
    steps = n_scan_steps(cfg)
    k_embed, k_layers, k_head = jax.random.split(key, 3)

    def one_step(k):
        sub_keys = jax.random.split(k, len(kinds))
        if cfg.hybrid_period:
            return {f"pos{off}": _init_sublayer(cfg, sk, *kinds[off])
                    for off, sk in enumerate(sub_keys)}
        return _init_sublayer(cfg, sub_keys[0], *kinds[0])

    layer_keys = jax.random.split(k_layers, steps)
    layers = jax.vmap(one_step)(layer_keys)

    v_pad = padded_vocab(cfg.vocab_size)
    params: Dict[str, Any] = {
        "embed": embed_init(k_embed, (v_pad, cfg.d_model), pd),
        "layers": layers,
        "final_norm": init_norm(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(k_head, (cfg.d_model, v_pad), pd)
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, s_max: int, dtype=jnp.bfloat16) -> PyTree:
    """Zeroed decode cache, scan-stacked over layers/periods."""
    steps = n_scan_steps(cfg)
    kinds = layer_kinds(cfg)

    def sub_cache(mixer: str) -> PyTree:
        if mixer == "attn":
            hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
            return {"k": jnp.zeros((steps, batch, s_max, hkv, hd), dtype),
                    "v": jnp.zeros((steps, batch, s_max, hkv, hd), dtype)}
        if mixer == "mla":
            m = cfg.mla
            return {"ckv": jnp.zeros((steps, batch, s_max, m.kv_lora_rank), dtype),
                    "kpe": jnp.zeros((steps, batch, s_max, m.qk_rope_head_dim), dtype)}
        s = cfg.ssm
        d_in, H, P, N, _ = ssd.ssm_dims(cfg)
        gn = s.n_groups * s.d_state
        return {"conv_x": jnp.zeros((steps, batch, s.d_conv - 1, d_in), dtype),
                "conv_B": jnp.zeros((steps, batch, s.d_conv - 1, gn), dtype),
                "conv_C": jnp.zeros((steps, batch, s.d_conv - 1, gn), dtype),
                "ssm": jnp.zeros((steps, batch, H, P, N), jnp.float32)}

    if cfg.hybrid_period:
        return {f"pos{off}": sub_cache(kinds[off][0]) for off in range(len(kinds))}
    return sub_cache(kinds[0][0])


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _run_sublayer(
    cfg: ModelConfig,
    p: dict,
    kind: Tuple[str, str],
    x: jax.Array,
    *,
    positions: jax.Array,
    mode: str,
    cache: Optional[PyTree],
    pos: Optional[jax.Array],
    use_kernel: bool,
) -> Tuple[jax.Array, Optional[PyTree], jax.Array]:
    mixer, f = kind
    sp = "sp" if mode == "train" else None
    h = apply_norm(cfg, p["mixer_norm"], x)
    if mixer == "attn":
        out, new_cache = attn.gqa_attention(
            cfg, p["mixer"], h, positions=positions, mode=mode,
            cache=cache, pos=pos, use_kernel=use_kernel)
    elif mixer == "mla":
        out, new_cache = attn.mla_attention(
            cfg, p["mixer"], h, positions=positions, mode=mode,
            cache=cache, pos=pos)
    else:
        out, new_cache = ssd.ssm_block(
            cfg, p["mixer"], h, mode=mode,
            state=cache, use_kernel=use_kernel)
    # pin the TP partial-sum output to the sequence-parallel layout BEFORE
    # the residual add: the cross-model reduction lowers to reduce-scatter
    # instead of all-reduce (halves activation wire bytes under SP)
    x = x + constrain(out, "batch", sp, None)

    aux = jnp.zeros((), jnp.float32)
    if f != "none":
        h = apply_norm(cfg, p["ffn_norm"], x)
        if f == "moe":
            out, aux = ffn.moe_ffn(cfg, p["ffn"], h, use_kernel=use_kernel)
        else:
            out = ffn.mlp(cfg, p["ffn"], h)
        x = x + constrain(out, "batch", sp, None)
    return x, new_cache, aux


def _remat_policy(name: Optional[str]):
    """Map a policy name to a jax.checkpoint policy.

    "nothing" (baseline): save only the scan carry — minimum memory,
    full forward recompute in backward (~1.33x flops).
    "dots": additionally save matmul outputs — less recompute, more memory.
    """
    if name == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    if name in (None, "nothing"):
        return jax.checkpoint_policies.nothing_saveable
    raise ValueError(f"unknown remat policy {name!r}")


def forward(
    cfg: ModelConfig,
    params: PyTree,
    tokens: jax.Array,                 # (B, S) int32
    *,
    mode: str = "train",               # train | prefill | decode
    positions: Optional[jax.Array] = None,
    cache: Optional[PyTree] = None,
    pos: Optional[jax.Array] = None,   # decode position (scalar int32)
    remat: bool = True,
    remat_policy: Optional[str] = "nothing",
    use_kernel: bool = False,
) -> Tuple[jax.Array, Optional[PyTree], jax.Array]:
    """Returns (hidden (B,S,d), new_cache, moe_aux_sum)."""
    B, S = tokens.shape
    kinds = layer_kinds(cfg)
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.activ_dtype))
    x = constrain(x, "batch", "sp" if mode == "train" else None, None)

    if positions is None:
        if mode == "decode":
            p = jnp.asarray(pos, dtype=jnp.int32)
            positions = (jnp.full((B, 1), p) if p.ndim == 0
                         else p[:, None])                # per-slot positions
        else:
            positions = jnp.arange(S, dtype=jnp.int32)
        if cfg.pos_type == "mrope":
            positions = jnp.broadcast_to(positions, (3, B, S)) if positions.ndim == 2 \
                else jnp.broadcast_to(positions[None, None, :], (3, B, S))

    want_cache = mode in ("prefill", "decode")

    def body_fn(x, step_in):
        lp, lc = step_in
        aux_total = jnp.zeros((), jnp.float32)
        if cfg.hybrid_period:
            new_lc = {}
            for off, kind in enumerate(kinds):
                sub_c = lc[f"pos{off}"] if lc is not None else None
                x, sc, aux = _run_sublayer(
                    cfg, lp[f"pos{off}"], kind, x,
                    positions=positions, mode=mode, cache=sub_c, pos=pos,
                    use_kernel=use_kernel)
                x = constrain(x, "batch", "sp" if mode == "train" else None, None)
                new_lc[f"pos{off}"] = sc
                aux_total = aux_total + aux
        else:
            x, new_lc, aux = _run_sublayer(
                cfg, lp, kinds[0], x,
                positions=positions, mode=mode, cache=lc, pos=pos,
                use_kernel=use_kernel)
            x = constrain(x, "batch", "sp" if mode == "train" else None, None)
            aux_total = aux_total + aux
        return x, (new_lc, aux_total)

    if remat:
        body_fn = jax.checkpoint(body_fn, policy=_remat_policy(remat_policy),
                                 prevent_cse=False)

    xs = (params["layers"], cache) if want_cache else (params["layers"], None)
    if not want_cache:
        # scan without cache leaves: thread params only
        def body_nocache(x, lp):
            return body_fn(x, (lp, None))
        x, (new_cache, aux_steps) = jax.lax.scan(body_nocache, x, params["layers"])
        new_cache = None
    else:
        x, (new_cache, aux_steps) = jax.lax.scan(body_fn, x, xs)

    x = apply_norm(cfg, params["final_norm"], x)
    return x, new_cache, jnp.sum(aux_steps)


def logits_fn(cfg: ModelConfig, params: PyTree, hidden: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        return hidden @ params["embed"].T
    return hidden @ params["lm_head"]


# ---------------------------------------------------------------------------
# losses / serving entry points
# ---------------------------------------------------------------------------


def cross_entropy(
    cfg: ModelConfig,
    params: PyTree,
    hidden: jax.Array,     # (B, S, d)
    targets: jax.Array,    # (B, S) int32
    mask: Optional[jax.Array] = None,
    chunk: Optional[int] = None,
) -> jax.Array:
    """Token-mean next-token CE with fp32 log-softmax.

    `chunk` chunks the sequence axis so the (B, S, V) logits tensor is never
    materialized (critical for 256k vocabs at train shapes).
    """
    B, S, d = hidden.shape
    if mask is None:
        mask = jnp.ones((B, S), dtype=jnp.float32)

    v_pad = padded_vocab(cfg.vocab_size)
    vmask = (vocab_mask(cfg.vocab_size, v_pad)
             if v_pad != cfg.vocab_size else None)

    def chunk_loss(h, t, m):
        logits = logits_fn(cfg, params, h).astype(jnp.float32)
        if vmask is not None:
            logits = logits + vmask
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * m)

    if chunk is None or chunk >= S:
        total = chunk_loss(hidden, targets, mask)
    else:
        assert S % chunk == 0
        nc = S // chunk
        hc = hidden.reshape(B, nc, chunk, d).transpose(1, 0, 2, 3)
        tc = targets.reshape(B, nc, chunk).transpose(1, 0, 2)
        mc = mask.reshape(B, nc, chunk).transpose(1, 0, 2)

        def body(acc, inp):
            h, t, m = inp
            return acc + jax.checkpoint(chunk_loss)(h, t, m), None

        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, tc, mc))
    return total / jnp.maximum(jnp.sum(mask), 1.0)


def train_loss(
    cfg: ModelConfig,
    params: PyTree,
    batch: Dict[str, jax.Array],
    *,
    aux_weight: float = 0.01,
    loss_chunk: Optional[int] = None,
    remat_policy: Optional[str] = "nothing",
    use_kernel: bool = False,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    tokens = batch["tokens"]
    positions = batch.get("positions")
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    if positions is not None:
        positions = positions[..., :-1]
    hidden, _, aux = forward(
        cfg, params, inp, mode="train", positions=positions,
        remat_policy=remat_policy, use_kernel=use_kernel)
    ce = cross_entropy(cfg, params, hidden, tgt,
                       mask=batch.get("loss_mask"), chunk=loss_chunk)
    loss = ce + aux_weight * aux
    return loss, {"ce": ce, "moe_aux": aux}


def prefill(
    cfg: ModelConfig,
    params: PyTree,
    batch: Dict[str, jax.Array],
    *,
    use_kernel: bool = False,
) -> Tuple[jax.Array, PyTree]:
    """Returns (last-token logits (B, V), populated cache).

    ``batch`` may carry ``true_len`` (scalar int32): the prompt is then
    treated as right-padded to the token buffer's length and the logits
    are read at position ``true_len - 1`` instead of the last position.
    With causal attention the positions below ``true_len`` never see the
    padding, so a padded-bucket prefill is bit-for-bit equivalent at the
    read position — this is what lets serving engines compile a few
    bucket shapes instead of one executable per prompt length.
    """
    tokens = batch["tokens"]
    hidden, cache, _ = forward(
        cfg, params, tokens, mode="prefill",
        positions=batch.get("positions"), remat=False, use_kernel=use_kernel)
    true_len = batch.get("true_len")
    if true_len is None:
        last = hidden[:, -1:, :]
    else:
        last = jax.lax.dynamic_slice_in_dim(hidden, true_len - 1, 1, axis=1)
    logits = logits_fn(cfg, params, last)[:, 0, :]
    return logits, cache


def decode_step(
    cfg: ModelConfig,
    params: PyTree,
    tokens: jax.Array,      # (B, 1)
    cache: PyTree,
    pos: jax.Array,         # scalar int32 — current write position
) -> Tuple[jax.Array, PyTree]:
    """One serving step: returns (logits (B, V), updated cache)."""
    hidden, new_cache, _ = forward(
        cfg, params, tokens, mode="decode", cache=cache, pos=pos, remat=False)
    logits = logits_fn(cfg, params, hidden[:, 0:1, :])[:, 0, :]
    return logits, new_cache
