"""Whisper-style encoder-decoder backbone.

The audio frontend (log-mel + conv) is a STUB per the assignment: inputs are
precomputed frame embeddings (B, F, d_model). Encoder adds sinusoidal
positions; decoder uses learned positions, causal self-attention with a KV
cache and cross-attention whose K/V are computed once at prefill and cached.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mlp as ffn
from repro.models.common import (
    apply_norm,
    embed_init,
    init_norm,
    padded_vocab,
    param_dtype_of,
    sinusoidal_positions,
)
from repro.sharding.ctx import constrain

PyTree = Any


def init_params(cfg: ModelConfig, key: jax.Array, max_seq: Optional[int] = None) -> PyTree:
    assert cfg.encdec is not None
    pd = param_dtype_of(cfg)
    max_seq = max_seq or min(cfg.max_seq_len, 32_768)
    k_embed, k_pos, k_enc, k_dec = jax.random.split(key, 4)

    def enc_layer(k):
        ks = jax.random.split(k, 2)
        return {
            "attn_norm": init_norm(cfg, cfg.d_model),
            "attn": attn.init_gqa(cfg, ks[0]),
            "mlp_norm": init_norm(cfg, cfg.d_model),
            "mlp": ffn.init_mlp(cfg, ks[1]),
        }

    def dec_layer(k):
        ks = jax.random.split(k, 3)
        return {
            "self_norm": init_norm(cfg, cfg.d_model),
            "self_attn": attn.init_gqa(cfg, ks[0]),
            "cross_norm": init_norm(cfg, cfg.d_model),
            "cross_attn": attn.init_cross_attn(cfg, ks[1]),
            "mlp_norm": init_norm(cfg, cfg.d_model),
            "mlp": ffn.init_mlp(cfg, ks[2]),
        }

    enc_keys = jax.random.split(k_enc, cfg.encdec.num_encoder_layers)
    dec_keys = jax.random.split(k_dec, cfg.num_layers)
    return {
        "embed": embed_init(k_embed, (padded_vocab(cfg.vocab_size), cfg.d_model), pd),
        "pos_embed": embed_init(k_pos, (max_seq, cfg.d_model), pd),
        "enc_layers": jax.vmap(enc_layer)(enc_keys),
        "enc_norm": init_norm(cfg, cfg.d_model),
        "dec_layers": jax.vmap(dec_layer)(dec_keys),
        "dec_norm": init_norm(cfg, cfg.d_model),
    }


def encode(cfg: ModelConfig, params: PyTree, frames: jax.Array, *,
           remat: bool = True) -> jax.Array:
    """frames: (B, F, d) stub frame embeddings -> encoder output (B, F, d)."""
    B, F, d = frames.shape
    x = frames.astype(jnp.dtype(cfg.activ_dtype))
    x = x + sinusoidal_positions(F, d).astype(x.dtype)[None]

    def body(x, lp):
        h = apply_norm(cfg, lp["attn_norm"], x)
        out, _ = attn.gqa_attention(cfg, lp["attn"], h,
                                    positions=jnp.arange(F, dtype=jnp.int32),
                                    mode="train", causal=False)
        x = x + out
        h = apply_norm(cfg, lp["mlp_norm"], x)
        x = x + ffn.mlp(cfg, lp["mlp"], h)
        return constrain(x, "batch", "sp", None), None

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable,
            prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return apply_norm(cfg, params["enc_norm"], x)


def _dec_sublayer(cfg, lp, x, *, positions, mode, self_cache, cross_cache,
                  enc_out, pos):
    h = apply_norm(cfg, lp["self_norm"], x)
    out, new_self = attn.gqa_attention(
        cfg, lp["self_attn"], h, positions=positions, mode=mode,
        cache=self_cache, pos=pos)
    x = x + out
    h = apply_norm(cfg, lp["cross_norm"], x)
    out, new_cross = attn.cross_attention(
        cfg, lp["cross_attn"], h, enc_out=enc_out, cache=cross_cache)
    x = x + out
    h = apply_norm(cfg, lp["mlp_norm"], x)
    x = x + ffn.mlp(cfg, lp["mlp"], h)
    return x, new_self, new_cross


def decode_stack(
    cfg: ModelConfig,
    params: PyTree,
    tokens: jax.Array,
    *,
    mode: str,
    enc_out: Optional[jax.Array] = None,
    cache: Optional[PyTree] = None,
    pos: Optional[jax.Array] = None,
    remat: bool = True,
) -> Tuple[jax.Array, Optional[PyTree]]:
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.activ_dtype))
    if mode == "decode":
        p = jnp.asarray(pos, dtype=jnp.int32)
        if p.ndim == 0:
            pe = jax.lax.dynamic_slice_in_dim(params["pos_embed"], p, 1, axis=0)[None]
            positions = jnp.full((B, 1), p, dtype=jnp.int32)
        else:
            pe = jnp.take(params["pos_embed"], p, axis=0)[:, None]   # (B,1,d)
            positions = p[:, None]
        x = x + pe.astype(x.dtype)
    else:
        x = x + params["pos_embed"][:S][None].astype(x.dtype)
        positions = jnp.arange(S, dtype=jnp.int32)

    def body(x, step_in):
        lp, lc = step_in
        sc = lc["self"] if lc is not None else None
        cc = lc["cross"] if lc is not None else None
        x, new_self, new_cross = _dec_sublayer(
            cfg, lp, x, positions=positions, mode=mode,
            self_cache=sc, cross_cache=cc, enc_out=enc_out, pos=pos)
        return constrain(x, "batch", "sp" if mode == "train" else None, None), {"self": new_self, "cross": new_cross}

    if remat and mode == "train":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable,
            prevent_cse=False)

    xs = (params["dec_layers"], cache)
    x, new_cache = jax.lax.scan(body, x, xs)
    x = apply_norm(cfg, params["dec_norm"], x)
    if mode == "train":
        new_cache = None
    return x, new_cache


def init_cache(cfg: ModelConfig, batch: int, s_max: int, enc_len: int,
               dtype=jnp.bfloat16) -> PyTree:
    L = cfg.num_layers
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "self": {"k": jnp.zeros((L, batch, s_max, hkv, hd), dtype),
                 "v": jnp.zeros((L, batch, s_max, hkv, hd), dtype)},
        "cross": {"k": jnp.zeros((L, batch, enc_len, hkv, hd), dtype),
                  "v": jnp.zeros((L, batch, enc_len, hkv, hd), dtype)},
    }


def logits_fn(cfg: ModelConfig, params: PyTree, hidden: jax.Array) -> jax.Array:
    return hidden @ params["embed"].T  # whisper ties embeddings


def train_loss(cfg: ModelConfig, params: PyTree, batch: Dict[str, jax.Array],
               *, loss_chunk: Optional[int] = None, **_) -> Tuple[jax.Array, Dict]:
    from repro.models.lm import cross_entropy  # shared CE

    frames, tokens = batch["frames"], batch["tokens"]
    enc_out = encode(cfg, params, frames)
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    hidden, _ = decode_stack(cfg, params, inp, mode="train", enc_out=enc_out)
    ce = cross_entropy(cfg, params, hidden, tgt, mask=batch.get("loss_mask"),
                       chunk=loss_chunk)
    return ce, {"ce": ce, "moe_aux": jnp.zeros((), jnp.float32)}


def prefill(cfg: ModelConfig, params: PyTree, batch: Dict[str, jax.Array],
            **_) -> Tuple[jax.Array, PyTree]:
    frames, tokens = batch["frames"], batch["tokens"]
    enc_out = encode(cfg, params, frames, remat=False)
    hidden, cache = decode_stack(cfg, params, tokens, mode="prefill",
                                 enc_out=enc_out, remat=False)
    logits = logits_fn(cfg, params, hidden[:, -1:, :])[:, 0, :]
    return logits, cache


def decode_step(cfg: ModelConfig, params: PyTree, tokens: jax.Array,
                cache: PyTree, pos: jax.Array) -> Tuple[jax.Array, PyTree]:
    hidden, new_cache = decode_stack(cfg, params, tokens, mode="decode",
                                     cache=cache, pos=pos, remat=False)
    logits = logits_fn(cfg, params, hidden[:, 0:1, :])[:, 0, :]
    return logits, new_cache
