"""Shared model primitives: norms, activations, rotary embeddings, init."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------


VOCAB_PAD_MULTIPLE = 256   # = 16 (model axis) x 16; keeps vocab dims shardable


def padded_vocab(vocab_size: int) -> int:
    m = VOCAB_PAD_MULTIPLE
    return (vocab_size + m - 1) // m * m


def vocab_mask(vocab_size: int, padded: int) -> jnp.ndarray:
    """(padded,) fp32 additive mask: 0 for real ids, -1e30 for padding."""
    return jnp.where(jnp.arange(padded) < vocab_size, 0.0, -1e30).astype(jnp.float32)


def dtype_of(cfg: ModelConfig) -> jnp.dtype:
    return jnp.dtype(cfg.activ_dtype)


def param_dtype_of(cfg: ModelConfig) -> jnp.dtype:
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# norms (fp32 accumulation, cast back to input dtype)
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    normed = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (normed * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def apply_norm(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.norm_type == "layernorm":
        return layernorm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rmsnorm(x, p["scale"], cfg.norm_eps)


def init_norm(cfg: ModelConfig, dim: int) -> dict:
    pd = param_dtype_of(cfg)
    p = {"scale": jnp.ones((dim,), dtype=pd)}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((dim,), dtype=pd)
    return p


def gated_rmsnorm(x: jax.Array, z: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Mamba2 RMSNormGated: rmsnorm(x * silu(z)) * scale."""
    xf = x.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------


def act_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name!r}")


# ---------------------------------------------------------------------------
# rotary embeddings (NeoX half-rotation convention)
# ---------------------------------------------------------------------------


def rope_freqs(rot_dim: int, theta: float) -> jax.Array:
    """(rot_dim/2,) inverse frequencies, fp32."""
    exponents = jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim
    return 1.0 / (theta ** exponents)


def rope_cos_sin(
    positions: jax.Array,  # (..., S) int32
    rot_dim: int,
    theta: float,
) -> Tuple[jax.Array, jax.Array]:
    """cos/sin of shape positions.shape + (rot_dim/2,), fp32."""
    inv = rope_freqs(rot_dim, theta)
    angles = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(angles), jnp.sin(angles)


def mrope_cos_sin(
    positions: jax.Array,  # (3, B, S) int32 — temporal/height/width streams
    rot_dim: int,
    theta: float,
    sections: Tuple[int, int, int],
) -> Tuple[jax.Array, jax.Array]:
    """Qwen2-VL M-RoPE: frequency index i uses the position stream of its
    section. Returns cos/sin of shape (B, S, rot_dim/2)."""
    assert sum(sections) == rot_dim // 2, (sections, rot_dim)
    inv = rope_freqs(rot_dim, theta)  # (rot_dim/2,)
    # section id for each frequency index
    sec_ids = jnp.concatenate([
        jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)
    ])  # (rot_dim/2,)
    # gather per-frequency positions: (B, S, rot_dim/2)
    pos_sel = jnp.take(positions, sec_ids, axis=0)          # (rot/2, B, S)
    pos_sel = jnp.moveaxis(pos_sel, 0, -1)                  # (B, S, rot/2)
    angles = pos_sel.astype(jnp.float32) * inv
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D) with rotary applied to the leading `2*cos.shape[-1]`
    dims of D. cos/sin: (B, S, rot/2) or (S, rot/2)."""
    rot = cos.shape[-1] * 2
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    if cos.ndim == 2:  # (S, rot/2) -> broadcast over batch
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:  # (B, S, rot/2)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    out = jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)
    if x_pass.shape[-1]:
        out = jnp.concatenate([out, x_pass], axis=-1)
    return out


def sinusoidal_positions(length: int, dim: int) -> jax.Array:
    """Whisper-style sinusoidal embeddings, (length, dim) fp32."""
    half = dim // 2
    freqs = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / (half - 1))
    args = jnp.arange(length, dtype=jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(args), jnp.cos(args)], axis=-1)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key: jax.Array, shape: Tuple[int, ...], dtype, scale: Optional[float] = None) -> jax.Array:
    fan_in = shape[0] if len(shape) >= 2 else max(shape[0], 1)
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, dtype=jnp.float32) * std).astype(dtype)


def embed_init(key: jax.Array, shape: Tuple[int, ...], dtype) -> jax.Array:
    return (jax.random.normal(key, shape, dtype=jnp.float32) * 0.02).astype(dtype)
