"""Attention variants: GQA (incl. MHA), MLA (latent), cross-attention.

All functions are pure; caches are explicit pytrees:
  GQA self-attn cache : {"k": (B, S_max, Hkv, Dh), "v": (B, S_max, Hkv, Dh)}
  MLA self-attn cache : {"ckv": (B, S_max, R), "kpe": (B, S_max, Dr)}
  cross-attn cache    : {"k": (B, S_enc, H, Dh), "v": (B, S_enc, H, Dh)}

Modes:
  train   — full-sequence causal (or bidirectional), no cache I/O
  prefill — full-sequence causal, returns the populated cache
  decode  — q_len==1 at position `pos`, reads+updates the cache
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig
from repro.models.common import (
    apply_rope,
    dense_init,
    init_norm,
    mrope_cos_sin,
    param_dtype_of,
    rmsnorm,
    rope_cos_sin,
)

Cache = Dict[str, jax.Array]

# above this sequence length, causal attention uses the chunked
# online-softmax path (never materializes S x S logits)
FLASH_THRESHOLD = 8192


def _full_attn(q, k, v, *, scale, causal, use_kernel):
    """Dispatch between plain sdpa, chunked flash ref, and the Pallas kernel.

    The flash path pins a sequence-parallel layout: q (and the output) shard
    the seq dim on the plan's seq axis while k/v stay replicated across it —
    every q-block program is then fully local (no per-block K gathers).
    """
    from repro.sharding.ctx import constrain

    S = q.shape[1]
    if use_kernel and causal:
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=True)
    if causal and S >= FLASH_THRESHOLD:
        from repro.kernels.ref import flash_attention_ref
        q = constrain(q, "batch", "seq", None, None)
        k = constrain(k, "batch", None, None, None)
        v = constrain(v, "batch", None, None, None)
        out = flash_attention_ref(q, k, v, causal=True, scale=scale)
        return constrain(out, "batch", "seq", None, None)
    return sdpa(q, k, v, scale=scale, causal=causal)


# ---------------------------------------------------------------------------
# core scaled-dot-product with GQA grouping
# ---------------------------------------------------------------------------


def sdpa(
    q: jax.Array,            # (B, Q, Hq, D)
    k: jax.Array,            # (B, S, Hkv, D)
    v: jax.Array,            # (B, S, Hkv, Dv)
    *,
    scale: float,
    causal: bool,
    q_offset: Optional[jax.Array] = None,   # scalar start position of q
    kv_len: Optional[jax.Array] = None,     # valid kv prefix length (decode)
    extra_logits: Optional[jax.Array] = None,  # (B, Hkv, G, Q, S) additive
) -> jax.Array:
    """Grouped-query attention with fp32 softmax. Returns (B, Q, Hq, Dv)."""
    B, Q, Hq, D = q.shape
    if k.dtype != q.dtype:   # low-precision (fp8) KV cache: upcast for math
        k = k.astype(q.dtype)
        v = v.astype(q.dtype)
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Q, Hkv, G, D)
    logits = jnp.einsum("bqhgd,bshd->bhgqs", qg, k).astype(jnp.float32) * scale
    if extra_logits is not None:
        logits = logits + extra_logits.astype(jnp.float32)

    S = k.shape[1]
    mask = None  # (B or 1, Q, S)
    if causal:
        q_pos = jnp.arange(Q)
        if q_offset is not None:
            q_pos = q_pos + q_offset
        k_pos = jnp.arange(S)
        mask = (k_pos[None, :] <= q_pos[:, None])[None]   # (1, Q, S)
    if kv_len is not None:
        kv_len = jnp.asarray(kv_len)
        if kv_len.ndim == 0:
            valid = (jnp.arange(S)[None, :] < kv_len)[None]        # (1,1,S)
        else:                                             # per-batch (B,)
            valid = jnp.arange(S)[None, None, :] < kv_len[:, None, None]
        mask = valid if mask is None else (mask & valid)
    if mask is not None:
        logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)

    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqs,bshd->bqhgd", w, v)
    return out.reshape(B, Q, Hq, v.shape[-1])


# ---------------------------------------------------------------------------
# GQA layer
# ---------------------------------------------------------------------------


def init_gqa(cfg: ModelConfig, key: jax.Array) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    pd = param_dtype_of(cfg)
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, hq * hd), pd),
        "wk": dense_init(ks[1], (d, hkv * hd), pd),
        "wv": dense_init(ks[2], (d, hkv * hd), pd),
        "wo": dense_init(ks[3], (hq * hd, d), pd, scale=(hq * hd) ** -0.5 / math.sqrt(2 * cfg.num_layers)),
    }


def _positional_cos_sin(cfg: ModelConfig, positions: jax.Array) -> Optional[Tuple[jax.Array, jax.Array]]:
    hd = cfg.resolved_head_dim
    if cfg.pos_type == "rope":
        # positions: (S,) or (B, S)
        return rope_cos_sin(positions, hd, cfg.rope_theta)
    if cfg.pos_type == "mrope":
        # positions: (3, B, S)
        return mrope_cos_sin(positions, hd, cfg.rope_theta, cfg.mrope_sections)
    return None


def gqa_attention(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,                       # (B, S, d)
    *,
    positions: jax.Array,               # rope: (S,)/(B,S); mrope: (3,B,S)
    mode: str = "train",                # train | prefill | decode
    causal: bool = True,
    cache: Optional[Cache] = None,
    pos: Optional[jax.Array] = None,    # decode write position (scalar)
    use_kernel: bool = False,
) -> Tuple[jax.Array, Optional[Cache]]:
    B, S, d = x.shape
    hd = cfg.resolved_head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    q = (x @ p["wq"]).reshape(B, S, hq, hd)
    k = (x @ p["wk"]).reshape(B, S, hkv, hd)
    v = (x @ p["wv"]).reshape(B, S, hkv, hd)

    cs = _positional_cos_sin(cfg, positions)
    if cs is not None:
        cos, sin = cs
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    scale = hd ** -0.5
    new_cache: Optional[Cache] = None
    if mode == "train":
        out = (_full_attn(q, k, v, scale=scale, causal=True, use_kernel=use_kernel)
               if causal else sdpa(q, k, v, scale=scale, causal=False))
    elif mode == "prefill":
        new_cache = {"k": k, "v": v}
        out = _full_attn(q, k, v, scale=scale, causal=causal, use_kernel=use_kernel)
    elif mode == "decode":
        assert cache is not None and pos is not None and S == 1
        pos = jnp.asarray(pos)
        k = k.astype(cache["k"].dtype)   # fp8 KV-cache path casts on write
        v = v.astype(cache["v"].dtype)
        if pos.ndim == 0:   # uniform batch position -> contiguous DUS
            k_cache = jax.lax.dynamic_update_slice(cache["k"], k, (0, pos, 0, 0))
            v_cache = jax.lax.dynamic_update_slice(cache["v"], v, (0, pos, 0, 0))
        else:               # per-slot positions (serving engine) -> scatter
            bidx = jnp.arange(B)
            k_cache = cache["k"].at[bidx, pos].set(k[:, 0].astype(cache["k"].dtype))
            v_cache = cache["v"].at[bidx, pos].set(v[:, 0].astype(cache["v"].dtype))
        new_cache = {"k": k_cache, "v": v_cache}
        out = sdpa(q, k_cache, v_cache, scale=scale, causal=False, kv_len=pos + 1)
    else:
        raise ValueError(mode)

    out = out.reshape(B, S, hq * hd) @ p["wo"]
    return out, new_cache


# ---------------------------------------------------------------------------
# cross-attention (whisper decoder)
# ---------------------------------------------------------------------------


def init_cross_attn(cfg: ModelConfig, key: jax.Array) -> dict:
    return init_gqa(cfg, key)


def cross_attention(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,                       # (B, S_dec, d)
    *,
    enc_out: Optional[jax.Array] = None,  # (B, S_enc, d) — train/prefill
    cache: Optional[Cache] = None,        # decode: precomputed enc k/v
) -> Tuple[jax.Array, Optional[Cache]]:
    B, S, d = x.shape
    hd = cfg.resolved_head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    q = (x @ p["wq"]).reshape(B, S, hq, hd)
    if cache is None:
        assert enc_out is not None
        k = (enc_out @ p["wk"]).reshape(B, enc_out.shape[1], hkv, hd)
        v = (enc_out @ p["wv"]).reshape(B, enc_out.shape[1], hkv, hd)
        new_cache = {"k": k, "v": v}
    else:
        k, v = cache["k"], cache["v"]
        new_cache = cache
    out = sdpa(q, k, v, scale=hd ** -0.5, causal=False)
    return out.reshape(B, S, hq * hd) @ p["wo"], new_cache


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention) — MiniCPM3 / DeepSeek-V2 style
# ---------------------------------------------------------------------------


def init_mla(cfg: ModelConfig, key: jax.Array) -> dict:
    m = cfg.mla or MLAConfig()
    d, hq = cfg.d_model, cfg.num_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    pd = param_dtype_of(cfg)
    ks = jax.random.split(key, 6)
    return {
        "w_dq": dense_init(ks[0], (d, m.q_lora_rank), pd),
        "q_norm": init_norm(cfg, m.q_lora_rank),
        "w_uq": dense_init(ks[1], (m.q_lora_rank, hq * qk_head), pd),
        "w_dkv": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim), pd),
        "kv_norm": init_norm(cfg, m.kv_lora_rank),
        "w_uk": dense_init(ks[3], (m.kv_lora_rank, hq * m.qk_nope_head_dim), pd),
        "w_uv": dense_init(ks[4], (m.kv_lora_rank, hq * m.v_head_dim), pd),
        "wo": dense_init(ks[5], (hq * m.v_head_dim, d), pd,
                         scale=(hq * m.v_head_dim) ** -0.5 / math.sqrt(2 * cfg.num_layers)),
    }


def _mla_q(cfg: ModelConfig, p: dict, x: jax.Array, cos, sin):
    m = cfg.mla or MLAConfig()
    B, S, _ = x.shape
    hq = cfg.num_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    q_lat = rmsnorm(x @ p["w_dq"], p["q_norm"]["scale"], cfg.norm_eps)
    q = (q_lat @ p["w_uq"]).reshape(B, S, hq, qk_head)
    q_nope, q_pe = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_pe = apply_rope(q_pe, cos, sin)
    return q_nope, q_pe


def _mla_latent_kv(cfg: ModelConfig, p: dict, x: jax.Array, cos, sin):
    m = cfg.mla or MLAConfig()
    ckv_kpe = x @ p["w_dkv"]
    ckv = ckv_kpe[..., : m.kv_lora_rank]
    kpe = ckv_kpe[..., m.kv_lora_rank:]
    ckv = rmsnorm(ckv, p["kv_norm"]["scale"], cfg.norm_eps)
    kpe = apply_rope(kpe[:, :, None, :], cos, sin)[:, :, 0, :]  # single shared head
    return ckv, kpe


def mla_attention(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,
    *,
    positions: jax.Array,
    mode: str = "train",
    cache: Optional[Cache] = None,
    pos: Optional[jax.Array] = None,
    absorbed_decode: bool = True,
) -> Tuple[jax.Array, Optional[Cache]]:
    """MLA with latent-compressed KV cache.

    Prefill/train use the expanded (materialized K/V) form. Decode defaults
    to the *absorbed* form: queries are projected into the latent space so
    attention runs directly against the (R + Dr)-wide cache — the classic
    MLA serving optimization (cache stays compressed, no per-step K/V
    re-expansion).
    """
    m = cfg.mla or MLAConfig()
    B, S, d = x.shape
    hq = cfg.num_heads
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    # rope over the full qk_rope_head_dim (rot_dim == qk_rope_head_dim)
    cos, sin = rope_cos_sin(positions, m.qk_rope_head_dim, cfg.rope_theta)

    q_nope, q_pe = _mla_q(cfg, p, x, cos, sin)

    if mode in ("train", "prefill"):
        ckv, kpe = _mla_latent_kv(cfg, p, x, cos, sin)
        k_nope = (ckv @ p["w_uk"]).reshape(B, S, hq, m.qk_nope_head_dim)
        v = (ckv @ p["w_uv"]).reshape(B, S, hq, m.v_head_dim)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(kpe[:, :, None, :], (B, S, hq, m.qk_rope_head_dim))], axis=-1)
        q = jnp.concatenate([q_nope, q_pe], axis=-1)
        if S >= FLASH_THRESHOLD:
            # MLA value dim != qk dim; flash ref handles D_v via padding
            from repro.kernels.ref import flash_attention_ref
            from repro.sharding.ctx import constrain
            dv = m.v_head_dim
            v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                                (0, q.shape[-1] - dv))) if q.shape[-1] != dv else v
            q = constrain(q, "batch", "seq", None, None)
            k = constrain(k, "batch", None, None, None)
            v_pad = constrain(v_pad, "batch", None, None, None)
            out = flash_attention_ref(q, k, v_pad, causal=True, scale=scale)[..., :dv]
            out = constrain(out, "batch", "seq", None, None)
        else:
            out = sdpa(q, k, v, scale=scale, causal=True)
        new_cache = {"ckv": ckv, "kpe": kpe} if mode == "prefill" else None
    elif mode == "decode":
        assert cache is not None and pos is not None and S == 1
        ckv_new, kpe_new = _mla_latent_kv(cfg, p, x, cos, sin)
        ckv_new = ckv_new.astype(cache["ckv"].dtype)
        kpe_new = kpe_new.astype(cache["kpe"].dtype)
        pos = jnp.asarray(pos)
        if pos.ndim == 0:
            ckv = jax.lax.dynamic_update_slice(cache["ckv"], ckv_new, (0, pos, 0))
            kpe = jax.lax.dynamic_update_slice(cache["kpe"], kpe_new, (0, pos, 0))
            valid = (jnp.arange(cache["ckv"].shape[1]) <= pos)[None, None, None, :]
        else:
            bidx = jnp.arange(B)
            ckv = cache["ckv"].at[bidx, pos].set(ckv_new[:, 0].astype(cache["ckv"].dtype))
            kpe = cache["kpe"].at[bidx, pos].set(kpe_new[:, 0].astype(cache["kpe"].dtype))
            valid = (jnp.arange(cache["ckv"].shape[1])[None, :]
                     <= pos[:, None])[:, None, None, :]            # (B,1,1,S)
        new_cache = {"ckv": ckv, "kpe": kpe}
        if ckv.dtype != x.dtype:   # fp8 KV cache: upcast for attention math
            ckv = ckv.astype(x.dtype)
            kpe = kpe.astype(x.dtype)
        S_max = ckv.shape[1]
        if absorbed_decode:
            # q_nope (B,1,H,Dn) @ w_uk per head -> latent query (B,1,H,R)
            w_uk = p["w_uk"].reshape(m.kv_lora_rank, hq, m.qk_nope_head_dim)
            q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)
            logits = (
                jnp.einsum("bqhr,bsr->bhqs", q_lat, ckv)
                + jnp.einsum("bqhd,bsd->bhqs", q_pe, kpe)
            ).astype(jnp.float32) * scale
            logits = jnp.where(valid, logits, -1e30)
            w = jax.nn.softmax(logits, axis=-1).astype(ckv.dtype)
            o_lat = jnp.einsum("bhqs,bsr->bqhr", w, ckv)          # (B,1,H,R)
            w_uv = p["w_uv"].reshape(m.kv_lora_rank, hq, m.v_head_dim)
            out = jnp.einsum("bqhr,rhd->bqhd", o_lat, w_uv)
        else:
            k_nope = (ckv @ p["w_uk"]).reshape(B, S_max, hq, m.qk_nope_head_dim)
            v = (ckv @ p["w_uv"]).reshape(B, S_max, hq, m.v_head_dim)
            k = jnp.concatenate(
                [k_nope, jnp.broadcast_to(kpe[:, :, None, :], (B, S_max, hq, m.qk_rope_head_dim))], axis=-1)
            q = jnp.concatenate([q_nope, q_pe], axis=-1)
            out = sdpa(q, k, v, scale=scale, causal=False, kv_len=pos + 1)
        out = out.reshape(B, S, hq * m.v_head_dim)
        return out @ p["wo"], new_cache
    else:
        raise ValueError(mode)

    out = out.reshape(B, S, hq * m.v_head_dim)
    return out @ p["wo"], new_cache
