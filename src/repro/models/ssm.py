"""Mamba2 (SSD — state-space duality) block.

Training/prefill use the chunked SSD algorithm (intra-chunk quadratic term
+ inter-chunk state recurrence); decode uses the O(1) recurrent update.
The chunked scan's hot loop has a Pallas kernel (`repro.kernels.ssd_scan`);
this module holds the pure-jnp formulation used for sharded lowering and as
the kernel oracle.

Projections are SPLIT (w_z/w_x/w_B/w_C/w_dt instead of one fused in_proj)
so tensor parallelism shards x/z/dt on SSD-head boundaries while the small
group-shared B/C/conv tensors stay replicated — a TPU adaptation: clean
head-aligned TP beats a fused projection whose sharded output dimension
would straddle the z|x|B|C|dt segment boundaries.

State pytree per layer:
  {"conv_x": (B, K-1, d_in), "conv_B": (B, K-1, G*N), "conv_C": (B, K-1, G*N),
   "ssm": (B, H, P, N) fp32}
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models.common import dense_init, gated_rmsnorm, param_dtype_of

State = Dict[str, jax.Array]


def ssm_dims(cfg: ModelConfig) -> Tuple[int, int, int, int, int]:
    """(d_inner, n_heads, head_dim, d_state, conv_dim)."""
    s = cfg.ssm or SSMConfig()
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return d_in, n_heads, s.head_dim, s.d_state, conv_dim


def init_ssm(cfg: ModelConfig, key: jax.Array) -> dict:
    s = cfg.ssm or SSMConfig()
    d = cfg.d_model
    d_in, H, P, N, _ = ssm_dims(cfg)
    gn = s.n_groups * N
    pd = param_dtype_of(cfg)
    ks = jax.random.split(key, 7)
    return {
        "w_z": dense_init(ks[0], (d, d_in), pd),
        "w_x": dense_init(ks[1], (d, d_in), pd),
        "w_B": dense_init(ks[2], (d, gn), pd),
        "w_C": dense_init(ks[3], (d, gn), pd),
        "w_dt": dense_init(ks[4], (d, H), pd),
        "conv_x_w": dense_init(ks[5], (s.d_conv, d_in), pd, scale=s.d_conv ** -0.5),
        "conv_x_b": jnp.zeros((d_in,), dtype=pd),
        "conv_B_w": dense_init(ks[6], (s.d_conv, gn), pd, scale=s.d_conv ** -0.5),
        "conv_B_b": jnp.zeros((gn,), dtype=pd),
        "conv_C_w": dense_init(ks[6], (s.d_conv, gn), pd, scale=s.d_conv ** -0.5),
        "conv_C_b": jnp.zeros((gn,), dtype=pd),
        "dt_bias": jnp.zeros((H,), dtype=jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), dtype=jnp.float32),
        "norm_scale": jnp.ones((d_in,), dtype=pd),
        "out_proj": dense_init(ks[3], (d_in, d), pd, scale=d_in ** -0.5),
    }


# ---------------------------------------------------------------------------
# chunked SSD scan (pure jnp reference; Pallas kernel mirrors this)
# ---------------------------------------------------------------------------


def _segsum(x: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{j < t <= i} x[..., t].

    Lower-triangular; -inf above the diagonal.
    """
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), dtype=bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_scan_ref(
    x: jax.Array,      # (B, S, H, P)
    dt: jax.Array,     # (B, S, H)  — post-softplus, fp32
    A: jax.Array,      # (H,)       — negative, fp32
    B_mat: jax.Array,  # (B, S, G, N)
    C_mat: jax.Array,  # (B, S, G, N)
    *,
    chunk: int,
    init_state: Optional[jax.Array] = None,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    Bb, S, H, P = x.shape
    G, N = B_mat.shape[2], B_mat.shape[3]
    S_orig = S
    if S % chunk:
        # zero-pad to a chunk multiple: dt=0 => decay 1 and zero state
        # contribution, so padding is exact for both y[:S] and final_state.
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_mat = jnp.pad(B_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_mat = jnp.pad(C_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S = S + pad
    nc = S // chunk
    rep = H // G

    f32 = jnp.float32
    xc = x.reshape(Bb, nc, chunk, H, P).astype(f32)
    dtc = dt.reshape(Bb, nc, chunk, H).astype(f32)
    Bc = B_mat.reshape(Bb, nc, chunk, G, N).astype(f32)
    Cc = C_mat.reshape(Bb, nc, chunk, G, N).astype(f32)
    Bc = jnp.repeat(Bc, rep, axis=3)  # (B, nc, L, H, N)
    Cc = jnp.repeat(Cc, rep, axis=3)

    dA = dtc * A  # (B, nc, L, H)
    dA_cum = jnp.cumsum(dA, axis=2)

    # NB: all einsums below are 2-operand contractions (batch dims b,c,h;
    # one contracted dim) so XLA lowers each to a single dot_general and
    # never materializes 6-D (b,c,l,h,p,n) intermediates.
    dtx = xc * dtc[..., None]                                 # (B, nc, L, H, P)

    # --- intra-chunk (diagonal blocks) ---
    L = jnp.exp(_segsum(jnp.moveaxis(dA, -1, -2)))          # (B, nc, H, L, L)
    scores = jnp.einsum("bclhn,bcshn->bchls", Cc, Bc)        # (B, nc, H, L, L)
    y_diag = jnp.einsum("bchls,bcshp->bclhp", scores * L, dtx)

    # --- chunk states ---
    decay_states = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)    # (B, nc, L, H)
    states = jnp.einsum("bclhn,bclhp->bchpn", Bc * decay_states[..., None], dtx)

    # --- inter-chunk recurrence ---
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])                # (B, nc, H)
    h0 = (init_state.astype(f32) if init_state is not None
          else jnp.zeros((Bb, H, P, N), dtype=f32))

    def step(h, inp):
        decay_c, state_c = inp                               # (B,H), (B,H,P,N)
        h_new = h * decay_c[..., None, None] + state_c
        return h_new, h

    decay_t = jnp.moveaxis(chunk_decay, 1, 0)                # (nc, B, H)
    states_t = jnp.moveaxis(states, 1, 0)                    # (nc, B, H, P, N)
    h_final, h_prev = jax.lax.scan(step, h0, (decay_t, states_t))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                      # (B, nc, H, P, N)

    # --- inter-chunk contribution ---
    state_decay = jnp.exp(dA_cum)                            # (B, nc, L, H)
    y_off = jnp.einsum("bclhn,bchpn->bclhp", Cc * state_decay[..., None], h_prev)

    y = (y_diag + y_off).reshape(Bb, S, H, P)[:, :S_orig]
    return y.astype(x.dtype), h_final


def ssd_step_ref(
    x: jax.Array,      # (B, H, P)
    dt: jax.Array,     # (B, H)
    A: jax.Array,      # (H,)
    B_vec: jax.Array,  # (B, G, N)
    C_vec: jax.Array,  # (B, G, N)
    h: jax.Array,      # (B, H, P, N) fp32
) -> Tuple[jax.Array, jax.Array]:
    """Single recurrent SSD step. Returns (y (B,H,P), h_new)."""
    G = B_vec.shape[1]
    rep = x.shape[1] // G
    Bh = jnp.repeat(B_vec, rep, axis=1).astype(jnp.float32)   # (B, H, N)
    Ch = jnp.repeat(C_vec, rep, axis=1).astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    dA = jnp.exp(dtf * A)                                     # (B, H)
    h_new = h * dA[..., None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dtf, x.astype(jnp.float32), Bh)
    y = jnp.einsum("bhpn,bhn->bhp", h_new, Ch)
    return y.astype(x.dtype), h_new


# ---------------------------------------------------------------------------
# full block
# ---------------------------------------------------------------------------


def _causal_conv(seq: jax.Array, w: jax.Array, b: jax.Array,
                 history: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv. seq: (B, S, C); w: (K, C). history: (B, K-1, C)."""
    K = w.shape[0]
    if history is None:
        pad = jnp.zeros((seq.shape[0], K - 1, seq.shape[2]), dtype=seq.dtype)
    else:
        pad = history.astype(seq.dtype)
    full = jnp.concatenate([pad, seq], axis=1)                 # (B, S+K-1, C)
    out = sum(full[:, i : i + seq.shape[1], :] * w[i][None, None, :] for i in range(K))
    return jax.nn.silu(out + b[None, None, :])


def _conv_history(seq: jax.Array, K: int) -> jax.Array:
    """Last K-1 raw inputs (pre-activation) for the decode conv state."""
    B, S, C = seq.shape
    if S >= K - 1:
        return seq[:, S - (K - 1):, :]
    zero = jnp.zeros((B, K - 1 - S, C), dtype=seq.dtype)
    return jnp.concatenate([zero, seq], axis=1)


def ssm_block(
    cfg: ModelConfig,
    p: dict,
    xin: jax.Array,                  # (B, S, d)
    *,
    mode: str = "train",
    state: Optional[State] = None,
    use_kernel: bool = False,
) -> Tuple[jax.Array, Optional[State]]:
    s = cfg.ssm or SSMConfig()
    Bb, S, d = xin.shape
    d_in, H, P, N, _ = ssm_dims(cfg)
    G = s.n_groups
    K = s.d_conv

    z = xin @ p["w_z"]
    x_raw = xin @ p["w_x"]
    B_raw = xin @ p["w_B"]
    C_raw = xin @ p["w_C"]
    dt_raw = xin @ p["w_dt"]                                   # (B, S, H)

    if mode == "decode":
        assert state is not None and S == 1
        x_act = _causal_conv(x_raw, p["conv_x_w"], p["conv_x_b"], state["conv_x"])
        B_act = _causal_conv(B_raw, p["conv_B_w"], p["conv_B_b"], state["conv_B"])
        C_act = _causal_conv(C_raw, p["conv_C_w"], p["conv_C_b"], state["conv_C"])
        new_conv = {
            "conv_x": jnp.concatenate([state["conv_x"][:, 1:], x_raw.astype(state["conv_x"].dtype)], axis=1),
            "conv_B": jnp.concatenate([state["conv_B"][:, 1:], B_raw.astype(state["conv_B"].dtype)], axis=1),
            "conv_C": jnp.concatenate([state["conv_C"][:, 1:], C_raw.astype(state["conv_C"].dtype)], axis=1),
        }
    else:
        x_act = _causal_conv(x_raw, p["conv_x_w"], p["conv_x_b"])
        B_act = _causal_conv(B_raw, p["conv_B_w"], p["conv_B_b"])
        C_act = _causal_conv(C_raw, p["conv_C_w"], p["conv_C_b"])
        new_conv = {
            "conv_x": _conv_history(x_raw, K),
            "conv_B": _conv_history(B_raw, K),
            "conv_C": _conv_history(C_raw, K),
        }

    x = x_act.reshape(Bb, S, H, P)
    B_mat = B_act.reshape(Bb, S, G, N)
    C_mat = C_act.reshape(Bb, S, G, N)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                                   # (H,) negative

    if mode == "decode":
        h = state["ssm"]
        y_core, h_new = ssd_step_ref(x[:, 0], dt[:, 0], A, B_mat[:, 0], C_mat[:, 0], h)
        y_core = y_core[:, None]                                # (B, 1, H, P)
        new_state: Optional[State] = dict(new_conv, ssm=h_new)
    else:
        init_h = state["ssm"] if state is not None else None
        if use_kernel:
            from repro.kernels import ops as kops
            y_core, h_new = kops.ssd_scan(x, dt, A, B_mat, C_mat, chunk=s.chunk_size)
        else:
            y_core, h_new = ssd_scan_ref(x, dt, A, B_mat, C_mat,
                                         chunk=s.chunk_size, init_state=init_h)
        new_state = dict(new_conv, ssm=h_new) if mode == "prefill" else None

    y = y_core + x * p["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(Bb, S, d_in).astype(xin.dtype)
    y = gated_rmsnorm(y, z, p["norm_scale"], cfg.norm_eps)
    return y @ p["out_proj"], new_state


def init_ssm_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> State:
    s = cfg.ssm or SSMConfig()
    d_in, H, P, N, _ = ssm_dims(cfg)
    gn = s.n_groups * N
    return {
        "conv_x": jnp.zeros((batch, s.d_conv - 1, d_in), dtype=dtype),
        "conv_B": jnp.zeros((batch, s.d_conv - 1, gn), dtype=dtype),
        "conv_C": jnp.zeros((batch, s.d_conv - 1, gn), dtype=dtype),
        "ssm": jnp.zeros((batch, H, P, N), dtype=jnp.float32),
    }
