"""Model zoo: 10 assigned architectures behind one functional API.

`build_model(config)` returns a `Model` with `init_params`, `train_loss`,
`prefill`, `decode_step`, `init_cache` — all pure functions suitable for
`jax.jit` / `pjit` with sharding plans from `repro.sharding`.
"""
from repro.models.api import Model, build_model  # noqa: F401
