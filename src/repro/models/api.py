"""Unified model facade over the decoder-only and enc-dec assemblies."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, lm

PyTree = Any


class Model:
    """Pure-function bundle for one architecture.

    All methods are jit/pjit-compatible; nothing here touches device state.
    """

    def __init__(self, cfg: ModelConfig, *, remat_policy: Optional[str] = "nothing",
                 loss_chunk: Optional[int] = None, use_kernel: bool = False):
        self.cfg = cfg
        self.remat_policy = remat_policy
        self.loss_chunk = loss_chunk
        self.use_kernel = use_kernel
        self._is_encdec = cfg.encdec is not None

    # ---- params ----
    def init_params(self, key: jax.Array, max_seq: Optional[int] = None) -> PyTree:
        if self._is_encdec:
            return encdec.init_params(self.cfg, key, max_seq=max_seq)
        return lm.init_params(self.cfg, key)

    def param_shapes(self, max_seq: Optional[int] = None) -> PyTree:
        key = jax.random.PRNGKey(0)
        return jax.eval_shape(lambda k: self.init_params(k, max_seq=max_seq), key)

    # ---- training ----
    def train_loss(self, params: PyTree, batch: Dict[str, jax.Array]
                   ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        if self._is_encdec:
            return encdec.train_loss(self.cfg, params, batch,
                                     loss_chunk=self.loss_chunk)
        return lm.train_loss(self.cfg, params, batch,
                             loss_chunk=self.loss_chunk,
                             remat_policy=self.remat_policy,
                             use_kernel=self.use_kernel)

    # ---- serving ----
    def prefill(self, params: PyTree, batch: Dict[str, jax.Array]
                ) -> Tuple[jax.Array, PyTree]:
        if self._is_encdec:
            return encdec.prefill(self.cfg, params, batch)
        return lm.prefill(self.cfg, params, batch, use_kernel=self.use_kernel)

    def decode_step(self, params: PyTree, tokens: jax.Array, cache: PyTree,
                    pos: jax.Array) -> Tuple[jax.Array, PyTree]:
        if self._is_encdec:
            return encdec.decode_step(self.cfg, params, tokens, cache, pos)
        return lm.decode_step(self.cfg, params, tokens, cache, pos)

    def init_cache(self, batch: int, s_max: int, dtype=jnp.bfloat16,
                   enc_len: Optional[int] = None) -> PyTree:
        if self._is_encdec:
            return encdec.init_cache(self.cfg, batch, s_max,
                                     enc_len=enc_len or self.cfg.encdec.encoder_seq_len,
                                     dtype=dtype)
        return lm.init_cache(self.cfg, batch, s_max, dtype=dtype)

    def cache_shapes(self, batch: int, s_max: int, dtype=jnp.bfloat16,
                     enc_len: Optional[int] = None) -> PyTree:
        return jax.eval_shape(
            lambda: self.init_cache(batch, s_max, dtype=dtype, enc_len=enc_len))


def build_model(cfg: ModelConfig, **kw) -> Model:
    return Model(cfg, **kw)
