"""Feed-forward layers: gated-SiLU / squared-ReLU / GELU MLPs and MoE.

The MoE uses the dense capacity-bucketed dispatch formulation (Switch/GShard
style einsums) so it shards cleanly under pjit: experts live on the `model`
mesh axis (expert parallelism) and dispatch/combine become all_to_all-like
collectives chosen by the partitioner. A Pallas top-k gating kernel
(`repro.kernels.moe_dispatch`) implements the routing hot-spot for TPU.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.common import act_fn, dense_init, param_dtype_of
from repro.sharding.ctx import constrain


# ---------------------------------------------------------------------------
# dense MLPs
# ---------------------------------------------------------------------------


def init_mlp(cfg: ModelConfig, key: jax.Array, d_ff: Optional[int] = None) -> dict:
    d = cfg.d_model
    ff = d_ff if d_ff is not None else cfg.d_ff
    pd = param_dtype_of(cfg)
    ks = jax.random.split(key, 3)
    out_scale = ff ** -0.5 / math.sqrt(2 * cfg.num_layers)
    p = {
        "w_up": dense_init(ks[0], (d, ff), pd),
        "w_down": dense_init(ks[1], (ff, d), pd, scale=out_scale),
    }
    if cfg.mlp_act == "silu":  # gated
        p["w_gate"] = dense_init(ks[2], (d, ff), pd)
    return p


def mlp(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    act = act_fn(cfg.mlp_act)
    if cfg.mlp_act == "silu":
        h = act(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = act(x @ p["w_up"])
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# mixture of experts
# ---------------------------------------------------------------------------


EXPERT_PAD_MULTIPLE = 16  # model-axis size; keeps the expert dim shardable


def padded_experts(num_experts: int) -> int:
    m = EXPERT_PAD_MULTIPLE
    return (num_experts + m - 1) // m * m


def init_moe(cfg: ModelConfig, key: jax.Array) -> dict:
    m = cfg.moe
    assert m is not None
    d, ff = cfg.d_model, m.d_expert
    e_pad = padded_experts(m.num_experts)  # pad experts never receive tokens
    pd = param_dtype_of(cfg)
    ks = jax.random.split(key, 5)
    out_scale = ff ** -0.5 / math.sqrt(2 * cfg.num_layers)
    p = {
        "router": dense_init(ks[0], (d, m.num_experts), jnp.float32, scale=0.02),
        "w_up": dense_init(ks[1], (e_pad, d, ff), pd),
        "w_down": dense_init(ks[2], (e_pad, ff, d), pd, scale=out_scale),
    }
    if cfg.mlp_act == "silu":
        p["w_gate"] = dense_init(ks[3], (e_pad, d, ff), pd)
    if m.num_shared_experts:
        p["shared"] = init_mlp(cfg, ks[4], d_ff=m.d_shared)
    return p


def router_topk(
    m: MoEConfig,
    logits: jax.Array,            # (T, E) fp32
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Top-k routing. Returns (weights (T,k), expert_idx (T,k), aux_loss)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, idx = jax.lax.top_k(probs, m.top_k)
    if m.norm_topk_prob:
        weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    T, E = logits.shape
    one_hot = jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32)
    f = jnp.mean(one_hot, axis=0)
    p_mean = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f * p_mean)
    return weights, idx, aux


EXACT_SMALL_G = 512   # groups up to this size dispatch drop-free (cap = g)
GROUP_SIZE = 1024     # tokens per dispatch group (GShard/MaxText style)


def moe_ffn(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,                  # (B, S, d)
    *,
    use_kernel: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Grouped capacity-bucketed dense-dispatch MoE. Returns (out, aux_loss).

    Tokens are reshaped into groups of <=GROUP_SIZE and dispatched within
    each group (GShard-style): the dispatch one-hots are O(g * E * cap) per
    group instead of O(T^2 k / E) globally, which is what makes 32k-token
    sequences tractable. Expert weights shard on the `model` axis (EP); the
    group dim shards on the batch axes, so the g<->(E,cap) einsums become
    the all-to-all dispatch/combine collectives under pjit.

    For small groups (decode steps, smoke tests) capacity is set to g, which
    is provably drop-free (an expert receives at most g slots per group) —
    decode is then *exactly* consistent with prefill.
    """
    m = cfg.moe
    assert m is not None
    B, S, d = x.shape
    T = B * S
    E, k = m.num_experts, m.top_k
    E_pad = padded_experts(E)
    xt = x.reshape(T, d)

    g = min(GROUP_SIZE, T)
    T_pad = (T + g - 1) // g * g
    if T_pad != T:
        xt = jnp.pad(xt, ((0, T_pad - T), (0, 0)))
    G = T_pad // g
    xg = xt.reshape(G, g, d)

    logits = xg.astype(jnp.float32) @ p["router"]           # (G, g, E)
    if use_kernel:
        from repro.kernels import ops as kops
        weights, idx = kops.moe_topk(logits.reshape(G * g, E), k,
                                     norm_topk=m.norm_topk_prob)
        weights = weights.reshape(G, g, k)
        idx = idx.reshape(G, g, k)
        _, _, aux = router_topk(m, logits.reshape(G * g, E))
    else:
        w_flat, i_flat, aux = router_topk(m, logits.reshape(G * g, E))
        weights, idx = w_flat.reshape(G, g, k), i_flat.reshape(G, g, k)

    # capacity per expert within a group
    if g <= EXACT_SMALL_G:
        cap = g                      # drop-free
    else:
        cap = max(1, int(math.ceil(g * k / E * m.capacity_factor)))
        cap = min(cap, g)

    # position of each (token, slot) within its per-group expert bucket
    e_one = jax.nn.one_hot(idx, E_pad, dtype=jnp.int32)     # (G, g, k, E_pad)
    flat = e_one.reshape(G, g * k, E_pad)
    pos_in_e = jnp.cumsum(flat, axis=1) - flat               # (G, g*k, E_pad)
    pos = jnp.sum(pos_in_e.reshape(G, g, k, E_pad) * e_one, axis=-1)  # (G, g, k)
    keep = pos < cap
    weights = weights * keep.astype(weights.dtype)

    # dispatch tensor (G, g, E_pad, cap)
    disp = jnp.einsum(
        "gske,gskc->gsec",
        jax.nn.one_hot(idx, E_pad, dtype=xt.dtype),
        jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                       dtype=xt.dtype)[..., :-1])
    x_e = jnp.einsum("gsec,gsd->gecd", disp, xg)             # (G, E_pad, cap, d)
    x_e = constrain(x_e, "batch", "ep", None, None)          # expert parallel

    act = act_fn(cfg.mlp_act)
    if cfg.mlp_act == "silu":
        h = act(jnp.einsum("gecd,edf->gecf", x_e, p["w_gate"])) * jnp.einsum(
            "gecd,edf->gecf", x_e, p["w_up"])
    else:
        h = act(jnp.einsum("gecd,edf->gecf", x_e, p["w_up"]))
    y_e = jnp.einsum("gecf,efd->gecd", h, p["w_down"])       # (G, E_pad, cap, d)
    y_e = constrain(y_e, "batch", "ep", None, None)

    combine = disp * jnp.sum(
        jax.nn.one_hot(idx, E_pad, dtype=weights.dtype) * weights[..., None],
        axis=2)[..., None]                                   # (G, g, E_pad, cap)
    out = jnp.einsum("gsec,gecd->gsd", combine.astype(y_e.dtype), y_e)

    out = out.reshape(T_pad, d)[:T]
    if m.num_shared_experts:
        out = out + mlp(cfg, p["shared"], xt[:T])
    return out.reshape(B, S, d), aux
