"""Label schema + fabric inventory (the paper's λ_N / λ_V label functions).

The cloud-edge testbed maps onto the TPU fabric as follows (DESIGN.md §2):
  * a POD is a site: it carries location / region / provider / security /
    zone labels (the paper's worker-node label matrix, Table 5);
  * within a pod, the ICI fabric is a 2-D torus over the (data, model) mesh
    axes; torus links and per-pod border routers are the network vertices
    (the paper's OpenFlow switches) and carry mfr / protocol / location /
    trusted labels (Table 4);
  * workload components (tenants, model blocks, KV caches, expert groups)
    are the paper's pods/services and carry app / data-type labels.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, FrozenSet, Iterable, List, Mapping, Optional, Tuple

Labels = Mapping[str, str]


def label_set(labels: Labels) -> FrozenSet[Tuple[str, str]]:
    return frozenset(labels.items())


# ---------------------------------------------------------------------------
# sites (pods)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Site:
    pod: int
    labels: Dict[str, str]


# the default two-pod production fabric — mirrors the paper's 5-worker label
# matrix (Table 5) at pod granularity, EU + US sites
DEFAULT_SITES = (
    Site(0, {"location": "london", "region": "eu", "provider": "aws",
             "security": "high", "zone": "cloud", "trusted": "yes"}),
    Site(1, {"location": "newyork", "region": "us", "provider": "azure",
             "security": "medium", "zone": "edge", "trusted": "yes"}),
)

# single-pod fabric used for the 16x16 mesh
SINGLE_SITE = (DEFAULT_SITES[0],)


# region ontology (the paper's "EU" -> concrete locations linking)
REGIONS: Dict[str, Tuple[str, ...]] = {
    "eu": ("london", "dublin", "frankfurt", "paris"),
    "us": ("newyork", "sanfrancisco", "oregon"),
    "apac": ("sydney", "tokyo", "singapore"),
    "cn": ("beijing", "shanghai"),
}


def region_of(location: str) -> Optional[str]:
    for region, locs in REGIONS.items():
        if location in locs:
            return region
    return None


# ---------------------------------------------------------------------------
# network vertices (switches / routers) and links
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NetVertex:
    vid: str                       # e.g. "pod0/sw_r3" or "pod0/border"
    kind: str                      # ici-switch | border-router | device
    labels: Dict[str, str]


@dataclasses.dataclass(frozen=True)
class NetLink:
    src: str
    dst: str
    bw: float                      # B/s
    labels: Dict[str, str]


@dataclasses.dataclass
class Fabric:
    """Device + network inventory for one deployment."""

    sites: Tuple[Site, ...]
    mesh_shape: Tuple[int, ...]            # e.g. (2, 16, 16) or (16, 16)
    axis_names: Tuple[str, ...]
    vertices: Dict[str, NetVertex] = dataclasses.field(default_factory=dict)
    links: List[NetLink] = dataclasses.field(default_factory=list)

    # ---- label functions -------------------------------------------------
    def site_of_pod(self, pod: int) -> Site:
        return self.sites[pod]

    def pod_labels(self, pod: int) -> Dict[str, str]:
        return dict(self.sites[pod].labels)

    def device_labels(self, device_index: int) -> Dict[str, str]:
        """λ_N for one device (flat index into the mesh)."""
        if "pod" in self.axis_names:
            pod_size = 1
            for n, s in zip(self.axis_names, self.mesh_shape):
                if n != "pod":
                    pod_size *= s
            pod = device_index // pod_size
        else:
            pod = 0
        labels = self.pod_labels(pod)
        labels["pod"] = str(pod)
        return labels

    def vertex_labels(self, vid: str) -> Dict[str, str]:
        """λ_V for one network vertex."""
        return dict(self.vertices[vid].labels)

    def pods(self) -> List[int]:
        return list(range(len(self.sites)))

    def devices_of_pod(self, pod: int) -> List[int]:
        if "pod" not in self.axis_names:
            return list(range(int(_prod(self.mesh_shape))))
        pod_size = int(_prod(self.mesh_shape)) // self.mesh_shape[self.axis_names.index("pod")]
        return list(range(pod * pod_size, (pod + 1) * pod_size))

    def label_inventory(self) -> Dict[str, FrozenSet[str]]:
        """All (key -> set of values) present anywhere — the validator's
        hallucination cross-check ("eu_region does not exist on any node")."""
        inv: Dict[str, set] = {}
        for site in self.sites:
            for k, v in site.labels.items():
                inv.setdefault(k, set()).add(v)
        for v in self.vertices.values():
            for k, val in v.labels.items():
                inv.setdefault(k, set()).add(val)
        inv.setdefault("region", set()).update(REGIONS.keys())
        return {k: frozenset(vs) for k, vs in inv.items()}


def _prod(xs: Iterable[int]) -> int:
    out = 1
    for x in xs:
        out *= x
    return out


# ---------------------------------------------------------------------------
# fabric construction
# ---------------------------------------------------------------------------

_SWITCH_VENDORS = ("cisco", "huawei", "juniper", "arista")


def build_fabric(mesh_shape: Tuple[int, ...], axis_names: Tuple[str, ...],
                 sites: Optional[Tuple[Site, ...]] = None) -> Fabric:
    """Model the ICI/DCN topology as a labeled graph.

    Each pod's (data x model) torus is aggregated into one ICI switch per
    data-row (16 row switches per pod) plus a per-pod border router; border
    routers interconnect over DCN. This is the granularity at which routing
    intents operate (the paper's 9/25-switch topologies are comparable).
    """
    if sites is None:
        sites = DEFAULT_SITES if "pod" in axis_names else SINGLE_SITE
    fabric = Fabric(sites=sites, mesh_shape=mesh_shape, axis_names=axis_names)
    n_pods = len(sites) if "pod" in axis_names else 1
    rows = mesh_shape[axis_names.index("data")]

    for pod in range(n_pods):
        site = sites[pod]
        for r in range(rows):
            vid = f"pod{pod}/sw_r{r}"
            fabric.vertices[vid] = NetVertex(
                vid, "ici-switch",
                {"mfr": _SWITCH_VENDORS[(pod + r) % len(_SWITCH_VENDORS)],
                 "protocol": "OF_13",
                 "location": site.labels["location"],
                 "region": site.labels.get("region", ""),
                 "trusted": "yes" if r % 8 else "no",   # one untrusted/8 rows
                 "role": "backup" if r == rows - 1 else "normal",
                 "pod": str(pod)})
            # hosts hang off their row switch (endpoints are hosts, not
            # switches — vendor/trust predicates never apply to endpoints)
            host = f"pod{pod}/host{r}"
            fabric.vertices[host] = NetVertex(
                host, "host",
                {"location": site.labels["location"],
                 "region": site.labels.get("region", ""),
                 "pod": str(pod)})
            fabric.links.append(NetLink(host, vid, 50e9, {"type": "access"}))
        border = f"pod{pod}/border"
        # border routers are vendor-neutral core devices, so vendor-avoid
        # paths can always detour row -> border -> row
        fabric.vertices[border] = NetVertex(
            border, "border-router",
            {"mfr": "neutral-core",
             "protocol": "OF_13",
             "location": site.labels["location"],
             "region": site.labels.get("region", ""),
             "trusted": "yes", "role": "border", "pod": str(pod)})
        # intra-pod ring over row switches + uplinks to border
        for r in range(rows):
            nxt = f"pod{pod}/sw_r{(r + 1) % rows}"
            fabric.links.append(NetLink(f"pod{pod}/sw_r{r}", nxt, 50e9,
                                        {"type": "ici"}))
            fabric.links.append(NetLink(f"pod{pod}/sw_r{r}", border, 25e9,
                                        {"type": "uplink"}))
    # DCN mesh between border routers
    for a, b in itertools.combinations(range(n_pods), 2):
        fabric.links.append(NetLink(f"pod{a}/border", f"pod{b}/border", 12.5e9,
                                    {"type": "dcn"}))
    return fabric


def match_labels(labels: Labels, predicate: Labels) -> bool:
    """predicate ⊆ labels, with region ontology expansion for 'location'."""
    for k, want in predicate.items():
        have = labels.get(k)
        if have == want:
            continue
        if k == "region" and have is None:
            loc = labels.get("location")
            if loc and region_of(loc) == want:
                continue
        return False
    return True
