"""The knowledge plane: intent interpretation behind an LLM-shaped interface.

The paper drives this with GPT-4o over the OpenAI API; this container is
offline, so the default backend is a deterministic semantic parser with the
SAME modular role structure the paper prompts for (§4.1):

  1. IntentClassifier  — computing / networking / hybrid
  2. StateChecker      — which infrastructure state to retrieve
  3. ServiceScheduler  — placement clauses -> structured directives
  4. PathPlanner       — routing clauses -> ⟨src, dst, must_go/avoid⟩ triples

Every role emits schema-validated JSON-able dicts ("do not include fields
outside the specified schema"); anything else is rejected fail-closed by
the orchestrator's safety layer, exactly like the paper treats LLM output
as a *suggested* plan.

`FaultyInterpreter` reproduces the paper's four observed failure modes
(§6.3) at a configurable rate so the validator's fail-closed behaviour and
the paper's accuracy comparisons (Fig. 7) can be exercised offline.
Plug a real LLM in by implementing `InterpreterBackend.complete`.

Token accounting mirrors the paper's metric: prompt tokens ≈ len(prompt)/4
(intent + condensed state snapshot) and completion tokens ≈ len(json)/4.
"""
from __future__ import annotations

import dataclasses
import json
import re
import time
from typing import Any, Dict, List, Optional, Protocol, Sequence, Tuple

from repro.core.intents import (
    Component,
    DEFAULT_WORKLOAD,
    Flow,
    Intent,
    PlacementConstraint,
    RoutingConstraint,
    ScalingConstraint,
    ServiceLevelConstraint,
)
from repro.core.labels import Fabric, REGIONS

# ---------------------------------------------------------------------------
# ontology (the paper's "ontological linking")
# ---------------------------------------------------------------------------

ONTOLOGY_DATA = {
    "phi": ("phi", "personal health", "health data", "patient data",
            "patient record", "sensitive data", "most sensitive",
            "medical record", "protected health"),
    "general": ("general", "non-sensitive", "public data"),
}

ONTOLOGY_APP = {
    "appointment": ("appointment",),
    "doctor": ("doctor",),
    "patient": ("patient service", "patient record", "patient microservice",
                "patient workload", "the patient"),
    "phi-db": ("phi database", "phi-db", "sensitive database",
               "medical database", "phi db"),
    "general-db": ("general database", "general-db", "general db"),
    "vital-sign-monitor": ("vital sign", "vital-sign", "monitor service"),
    "image-preprocessor": ("image preprocessor", "image-preprocessor"),
}

ONTOLOGY_SECURITY = {
    "high": ("high-security", "high security", "secure infrastructure",
             "trusted infrastructure", "high-trust", "high trust"),
    "low": ("low-security", "low security"),
}

ONTOLOGY_ZONE = {
    "cloud": ("cloud zone", "the cloud", "cloud nodes", "cloud node"),
    "edge": ("edge zone", "the edge", "edge nodes", "edge node"),
}

PROVIDERS = ("aws", "azure", "alibaba-cloud", "gcp")
VENDORS = ("huawei", "cisco", "juniper", "arista")

# capacity nouns + number words for scaling clauses ("keep at least two
# serving engines for phi traffic")
SCALING_NOUNS = ("engine", "engines", "replica", "replicas",
                 "instance", "instances")

# service-level metric phrases ("keep TTFT under 200 ms for phi traffic",
# "per-token latency below 20 milliseconds")
SLO_METRICS = {
    "ttft": ("ttft", "time to first token", "time-to-first-token",
             "first token", "first-token"),
    "tpot": ("tpot", "time per output token", "per-token latency",
             "per token latency", "token latency", "decode latency"),
}
# "<metric> under 200 ms" / "below 0.2 seconds" / "within 150ms"
_SLO_NUM = r"(\d+(?:\.\d+)?)\s*(ms|milliseconds?|s|sec|seconds?)\b"
_SLO_RE = re.compile(
    r"(?:under|below|within|less than|at most|<=?)\s+" + _SLO_NUM)
WORD_NUMS = {"one": 1, "two": 2, "three": 3, "four": 4, "five": 5,
             "six": 6, "seven": 7, "eight": 8, "nine": 9, "ten": 10}
# trailing \b keeps teen words from misparsing to their prefix
# ("fourteen" must not match as "four")
_NUM = r"(\d+|" + "|".join(WORD_NUMS) + r")\b"


def _to_int(tok: str) -> int:
    return WORD_NUMS[tok] if tok in WORD_NUMS else int(tok)


@dataclasses.dataclass
class InterpretResult:
    intent: Intent                     # structured output (compiled IR)
    classified_domain: str
    state_requests: Tuple[str, ...]    # what the StateChecker asked for
    directives: Dict[str, Any]         # raw JSON-able directives (auditable)
    prompt_tokens: int
    completion_tokens: int
    latency_s: float


class InterpreterBackend(Protocol):
    name: str

    def interpret(self, text: str, fabric: Fabric,
                  components: Sequence[Component]) -> InterpretResult: ...


# ---------------------------------------------------------------------------
# deterministic semantic parser backend
# ---------------------------------------------------------------------------


def _find_any(text: str, ontology: Dict[str, Tuple[str, ...]]) -> List[str]:
    found = []
    low = text.lower()
    for canon, phrases in ontology.items():
        if any(p in low for p in phrases) or canon in low:
            found.append(canon)
    return found


def _negated(text: str, phrase_pos: int) -> bool:
    window = text[max(0, phrase_pos - 60):phrase_pos].lower()
    return any(w in window for w in
               ("not ", "never", "avoid", "prohibit", "forbid", "prevent",
                "keep off", "exclude", "must not", "shouldn't", "outside",
                "ban ", "block "))


class DeterministicInterpreter:
    """Grammar + ontology parser implementing the four LLM roles."""

    name = "det-parser-v1"

    # ---- role 1: intent classifier ----
    def classify(self, text: str) -> str:
        low = text.lower()
        net_kw = any(w in low for w in
                     ("traffic", "route", "path", "switch", "flow", "link",
                      "traverse", "hop", "network", "packets"))
        comp_kw = any(w in low for w in
                      ("deploy", "schedule", "place", "run ", "host",
                       "node", "zone", "pod", "service", "database",
                       "workload", "reside", "stay", "remain", "stored"))
        if net_kw and comp_kw:
            return "hybrid"
        if net_kw:
            return "networking"
        return "computing"

    # ---- role 2: state checker ----
    def state_requests(self, domain: str) -> Tuple[str, ...]:
        reqs = []
        if domain in ("computing", "hybrid"):
            reqs += ["k8s/node_labels", "k8s/pod_placement"]
        if domain in ("networking", "hybrid"):
            reqs += ["onos/topology", "onos/hosts", "onos/flows"]
        return tuple(reqs)

    # ---- role 3+4: schedulers ----
    def interpret(self, text: str, fabric: Fabric,
                  components: Sequence[Component]) -> InterpretResult:
        t0 = time.time()
        domain = self.classify(text)
        state = self.state_requests(domain)
        low = text.lower()

        placement: List[PlacementConstraint] = []
        routing: List[RoutingConstraint] = []
        scaling: List[ScalingConstraint] = []
        service: List[ServiceLevelConstraint] = []

        # --- clause splitting (the paper's countermeasure to first-clause
        # capture: decompose multi-clause sentences) ---
        clauses = re.split(r"(?:, and |; | and also |, then |\. )", low)
        if len(clauses) == 1:
            clauses = [low]

        for clause in clauses:
            # a clause can carry capacity AND placement/routing predicates
            # ("at least two patient instances in the cloud zone") — parse
            # all four grammars; each only emits when its own predicates
            # are present, so a pure capacity clause adds nothing else
            scaling += self._scaling_clauses(clause)
            service += self._service_clauses(clause)
            placement += self._placement_clauses(clause)
            routing += self._routing_clauses(clause)

        # fold whole-sentence context for clauses the splitter separated from
        # their subjects
        if not placement and not routing and not scaling and not service:
            placement += self._placement_clauses(low)
            routing += self._routing_clauses(low)
            scaling += self._scaling_clauses(low)
            service += self._service_clauses(low)

        routing = self._merge_orphan_routing(routing, low)

        directives = {
            "domain": domain,
            "placement": [dataclasses.asdict(p) for p in placement],
            "routing": [dataclasses.asdict(r) for r in routing],
            "scaling": [dataclasses.asdict(s) for s in scaling],
            "service": [dataclasses.asdict(s) for s in service],
        }
        snapshot = json.dumps(sorted(fabric.label_inventory().items(),
                                     key=str), default=str)
        prompt_tokens = (len(text) + len(snapshot) + 800) // 4  # + role prompts
        completion_tokens = max(len(json.dumps(directives)) // 4, 16)

        intent = Intent(
            text=text, domain=domain,
            complexity="complex" if (len(placement) + len(routing)
                                     + len(scaling) + len(service) > 1
                                     or domain == "hybrid") else "simple",
            placement=tuple(placement), routing=tuple(routing),
            scaling=tuple(scaling), service=tuple(service))
        return InterpretResult(
            intent=intent, classified_domain=domain, state_requests=state,
            directives=directives, prompt_tokens=prompt_tokens,
            completion_tokens=completion_tokens, latency_s=time.time() - t0)

    # ---- placement clause grammar ----
    def _placement_clauses(self, clause: str) -> List[PlacementConstraint]:
        out: List[PlacementConstraint] = []
        subjects = _find_any(clause, ONTOLOGY_APP)
        data_types = _find_any(clause, ONTOLOGY_DATA)
        selector: Dict[str, str] = {}
        if subjects:
            selector["app"] = subjects[0]
        elif data_types:
            selector["data-type"] = data_types[0]
        elif any(w in clause for w in ("financial", "billing")):
            # paper Table 6: unenforceable selector — parser passes it through
            # and the validator fails closed
            selector["app"] = "financial-db"
        else:
            return out

        require: Dict[str, str] = {}
        forbid: Dict[str, str] = {}

        # regions / locations
        for region in REGIONS:
            pats = {"eu": ("european union", "the eu", " eu ", "eu-only", "europe"),
                    "us": ("united states", "the us", " us ", "u.s."),
                    "apac": ("apac", "asia-pacific", "australia"),
                    "cn": ("china",)}[region] if region in ("eu", "us", "apac", "cn") else (region,)
            for p in pats:
                pos = clause.find(p)
                if pos >= 0:
                    (forbid if _negated(clause, pos) else require)["region"] = region
        for locs in REGIONS.values():
            for loc in locs:
                pos = clause.find(loc)
                if pos >= 0:
                    (forbid if _negated(clause, pos) else require)["location"] = loc

        # zones
        for zone in ("cloud", "edge"):
            for p in ONTOLOGY_ZONE[zone]:
                pos = clause.find(p)
                if pos >= 0:
                    (forbid if _negated(clause, pos) else require)["zone"] = zone

        # security tiers
        for tier, phrases in ONTOLOGY_SECURITY.items():
            for p in phrases:
                pos = clause.find(p)
                if pos >= 0:
                    if tier == "low" and not _negated(clause, pos):
                        # "never on low-security" idioms arrive as forbids
                        forbid["security"] = "low"
                    else:
                        (forbid if _negated(clause, pos) else require)["security"] = tier

        # providers
        for prov in PROVIDERS:
            pos = clause.find(prov.split("-")[0])
            if pos >= 0:
                (forbid if _negated(clause, pos) else require)["provider"] = prov

        if require or forbid or selector.get("app") == "financial-db":
            out.append(PlacementConstraint(
                selector=tuple(sorted(selector.items())),
                require=tuple(sorted(require.items())),
                forbid=tuple(sorted(forbid.items()))))
        return out

    # ---- scaling clause grammar (runtime capacity: autoscaler bounds) ----
    def _scaling_clauses(self, clause: str) -> List[ScalingConstraint]:
        if not any(n in clause for n in SCALING_NOUNS):
            return []
        lo: Optional[int] = None
        hi: Optional[int] = None
        m = re.search(r"between\s+%s\s+and\s+%s" % (_NUM, _NUM), clause)
        if m:
            lo, hi = _to_int(m.group(1)), _to_int(m.group(2))
        m = re.search(r"at\s+least\s+%s" % _NUM, clause)
        if m:
            lo = _to_int(m.group(1))
        m = re.search(r"(?:at\s+most|no\s+more\s+than|up\s+to)\s+%s" % _NUM,
                      clause)
        if m:
            hi = _to_int(m.group(1))
        m = re.search(r"exactly\s+%s" % _NUM, clause)
        if m:
            lo = hi = _to_int(m.group(1))
        if lo is None and hi is None:
            return []

        subjects = _find_any(clause, ONTOLOGY_APP)
        data_types = _find_any(clause, ONTOLOGY_DATA)
        selector: Dict[str, str] = {}
        if subjects:
            selector["app"] = subjects[0]
        elif data_types:
            selector["data-type"] = data_types[0]
        else:
            return []      # capacity clause with no workload subject
        return [ScalingConstraint(selector=tuple(sorted(selector.items())),
                                  min_engines=lo or 0, max_engines=hi)]

    # ---- service-level clause grammar (latency targets: planner SLOs) ----
    def _service_clauses(self, clause: str) -> List[ServiceLevelConstraint]:
        """Parse latency-target clauses ("keep TTFT under 200 ms for phi
        traffic") into `ServiceLevelConstraint`s. A clause only emits
        when BOTH a recognized metric phrase and a bounded number with a
        time unit are present; the workload subject resolves through the
        same app/data-type ontology the other grammars use.

        Each metric binds to the first bound stated AFTER its own phrase
        ("TTFT under 200 ms and TPOT under 20 ms" must not relax TPOT to
        200 ms), and TTFT phrase spans are masked before TPOT matching
        ("first token latency" is a TTFT phrasing, not a per-token
        target)."""
        ttft_spans = [(m.start(), m.end())
                      for p in SLO_METRICS["ttft"]
                      for m in re.finditer(re.escape(p), clause)]
        positions: Dict[str, int] = {}
        if ttft_spans:
            positions["ttft"] = min(s for s, _ in ttft_spans)
        tpot_hits = [m.start()
                     for p in SLO_METRICS["tpot"]
                     for m in re.finditer(re.escape(p), clause)
                     if not any(s <= m.start() < e for s, e in ttft_spans)]
        if tpot_hits:
            positions["tpot"] = min(tpot_hits)
        if not positions:
            return []
        bounds = list(_SLO_RE.finditer(clause))
        if not bounds:
            return []

        def seconds(m) -> float:
            v = float(m.group(1))
            return v / 1e3 if m.group(2).startswith("m") else v

        targets: Dict[str, float] = {}
        for metric, pos in positions.items():
            after = [b for b in bounds if b.start() > pos]
            v = seconds(after[0] if after else bounds[0])
            if v > 0:
                targets[metric] = v
        if not targets:
            return []

        subjects = _find_any(clause, ONTOLOGY_APP)
        data_types = _find_any(clause, ONTOLOGY_DATA)
        selector: Dict[str, str] = {}
        if subjects:
            selector["app"] = subjects[0]
        elif data_types:
            selector["data-type"] = data_types[0]
        else:
            return []      # latency clause with no workload subject
        return [ServiceLevelConstraint(
            selector=tuple(sorted(selector.items())),
            max_ttft_s=targets.get("ttft"),
            max_tpot_s=targets.get("tpot"))]

    def _merge_orphan_routing(self, routing: List[RoutingConstraint],
                              full_text: str) -> List[RoutingConstraint]:
        """Clause splitting can orphan a predicate from its subject ("..., and
        never cross untrusted switches"): merge endpoint-less, selector-less
        constraints into the preceding routing constraint, or scope them by a
        whole-sentence data selector (the paper's decomposition
        countermeasure to first-clause capture)."""
        merged: List[RoutingConstraint] = []
        for rc in routing:
            orphan = (rc.flow.src == "*" and rc.flow.dst == "*"
                      and not rc.selector and not rc.waypoints)
            if orphan and merged:
                prev = merged[-1]
                merged[-1] = dataclasses.replace(
                    prev,
                    forbid_vertex=tuple(dict.fromkeys(
                        prev.forbid_vertex + rc.forbid_vertex)),
                    forbidden_axes=tuple(dict.fromkeys(
                        prev.forbidden_axes + rc.forbidden_axes)))
                continue
            if orphan:
                data_types = _find_any(full_text, ONTOLOGY_DATA)
                if data_types:
                    rc = dataclasses.replace(
                        rc, selector=(("data-type", data_types[0]),))
            merged.append(rc)
        return merged

    # ---- routing clause grammar ----
    def _routing_clauses(self, clause: str) -> List[RoutingConstraint]:
        out: List[RoutingConstraint] = []
        if not any(w in clause for w in ("traffic", "path", "route", "switch",
                                         "traverse", "flow", "hop", "link",
                                         "packets")):
            return out

        # endpoints: "host 2", "from X to Y", component names
        hosts = re.findall(r"host\s*(\d+)", clause)
        apps = _find_any(clause, ONTOLOGY_APP)
        data_types = _find_any(clause, ONTOLOGY_DATA)

        src, dst = "*", "*"
        m = re.search(r"from\s+(host\s*\d+|[\w-]+)\s+to\s+(host\s*\d+|[\w-]+)",
                      clause)
        if m:
            src = m.group(1).replace(" ", "")
            dst = m.group(2).replace(" ", "")
        elif len(hosts) >= 2:
            src, dst = f"host{hosts[0]}", f"host{hosts[1]}"
        elif len(hosts) == 1:
            dst = f"host{hosts[0]}"
        elif data_types and any(p in clause for p in
                                ("traffic", "flows", "flow", "data")):
            pass  # selector-scoped flows ("all phi traffic ...")
        elif len(apps) >= 2:
            src, dst = apps[0], apps[1]
        elif len(apps) == 1:
            dst = apps[0]

        waypoints: List[str] = []
        for m2 in re.finditer(r"(?:switch\s+|through\s+|via\s+)s(\d+)", clause):
            if not _negated(clause, m2.start()):
                waypoints.append(f"s{m2.group(1)}")
        if "backup switch" in clause and not waypoints:
            waypoints.append("backup")

        forbid_vertex: List[Tuple[str, str]] = []
        for vendor in VENDORS:
            pos = clause.find(vendor)
            if pos >= 0 and _negated(clause, pos):
                forbid_vertex.append(("mfr", vendor))
        if "untrusted" in clause:
            forbid_vertex.append(("trusted", "no"))
        for region, locs in REGIONS.items():
            for loc in locs:
                pos = clause.find(loc)
                if pos >= 0 and _negated(clause, pos):
                    forbid_vertex.append(("location", loc))
        m3 = re.search(r"(?:avoid|not|never|outside)[^.]*region[- ](\w+)", clause)
        if m3:
            forbid_vertex.append(("region", m3.group(1)))

        forbidden_axes: Tuple[str, ...] = ()
        if any(p in clause for p in ("stay within the pod", "inside the pod",
                                     "leave the pod", "within pod",
                                     "within the pod", "cross-pod",
                                     "leave the site")):
            forbidden_axes = ("pod",)
        selector: Tuple[Tuple[str, str], ...] = ()
        if data_types:
            selector = (("data-type", data_types[0]),)
            if data_types[0] == "phi" and any(
                    p in clause for p in ("never leave", "must stay", "remain")):
                forbidden_axes = ("pod",)

        if waypoints or forbid_vertex or forbidden_axes or (src, dst) != ("*", "*"):
            out.append(RoutingConstraint(
                flow=Flow(src, dst),
                forbid_vertex=tuple(forbid_vertex),
                waypoints=tuple(waypoints),
                forbidden_axes=forbidden_axes,
                selector=selector))
        return out


# ---------------------------------------------------------------------------
# degraded backends (paper §6.3 failure modes / Fig. 7 comparison shape)
# ---------------------------------------------------------------------------


class FaultyInterpreter(DeterministicInterpreter):
    """Injects the paper's observed failure modes at a configurable rate.

    modes: first_clause | empty_path | hallucinated_label | partial_topology
    """

    def __init__(self, name: str = "faulty", rate: float = 0.2,
                 modes: Sequence[str] = ("first_clause", "empty_path",
                                         "hallucinated_label",
                                         "partial_topology"),
                 seed: int = 0):
        self.name = name
        self.rate = rate
        self.modes = tuple(modes)
        self._seed = seed

    def interpret(self, text: str, fabric: Fabric,
                  components: Sequence[Component]) -> InterpretResult:
        res = super().interpret(text, fabric, components)
        # deterministic pseudo-randomness per intent text
        h = (hash((text, self._seed)) % 10_000) / 10_000
        if h >= self.rate:
            return res
        mode = self.modes[hash((text, "m", self._seed)) % len(self.modes)]
        intent = res.intent
        if mode == "first_clause" and (len(intent.placement)
                                       + len(intent.routing)) > 1:
            # keep only the first clause encountered
            if intent.placement:
                intent = dataclasses.replace(intent,
                                             placement=intent.placement[:1],
                                             routing=())
            else:
                intent = dataclasses.replace(intent, routing=intent.routing[:1])
        elif mode == "empty_path" and intent.routing:
            # drop src/dst -> no-op policy (validator flags "no applicable flow")
            r0 = intent.routing[0]
            intent = dataclasses.replace(
                intent, routing=(dataclasses.replace(
                    r0, flow=Flow("nonexistent-src", "nonexistent-dst")),)
                + intent.routing[1:])
        elif mode == "hallucinated_label" and intent.placement:
            p0 = intent.placement[0]
            intent = dataclasses.replace(
                intent, placement=(dataclasses.replace(
                    p0, require=(("region", "eu_region"),)),)
                + intent.placement[1:])
        elif mode == "partial_topology" and intent.routing:
            r0 = intent.routing[0]
            if r0.forbid_vertex:
                intent = dataclasses.replace(
                    intent, routing=(dataclasses.replace(
                        r0, forbid_vertex=r0.forbid_vertex[:-1]),)
                    + intent.routing[1:])
        res.intent = intent
        res.directives["injected_fault"] = mode
        return res
