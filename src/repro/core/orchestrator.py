"""The intent-driven orchestration loop (paper §4.2, steps A–F).

  (A) query network topology        (fabric graph / ONOS analogue)
  (B) query placement state         (component -> pod map / K8s analogue)
  (C) construct the enriched prompt (condensed state snapshot)
  (D) parse LLM response            (interpreter backend)
  (E) apply network flow rules      (install realized paths)
  (F) apply service placement       (commit pod assignments / plans)

Safety layer: the compiled policy is applied only if the validator passes
every atomic check (fail-closed) — LLM output is a *suggested* plan.

Runtime hook: `submit(text, apply_to=cluster)` pushes the validated policy
into a live `ServingCluster` — route constraints are installed and every
affected engine is reconfigured online (shardings materialized from the
compiled plan, prefill/decode AOT-compiled in the PREPARE phase, blocking
swap, DowntimeReport per engine in `result.reports`).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.compiler import CompiledPolicy, compile_intent
from repro.core.intents import Component, Configuration, DEFAULT_WORKLOAD
from repro.core.interpreter import DeterministicInterpreter, InterpreterBackend
from repro.core.labels import Fabric, build_fabric
from repro.core.validator import ValidationReport, validate


@dataclasses.dataclass
class FabricState:
    """Mutable run-time state of the deployment (the test-bed analogue)."""

    placement: Dict[str, int] = dataclasses.field(default_factory=dict)
    flows: Dict[Tuple[str, str], List[str]] = dataclasses.field(default_factory=dict)
    flow_rules: List[Dict] = dataclasses.field(default_factory=list)
    manifests: List[Dict] = dataclasses.field(default_factory=list)
    plans: Dict[str, object] = dataclasses.field(default_factory=dict)
    # data-type label -> (min, max) serving-engine bounds committed by
    # scaling intents (the HPA-manifest analogue)
    scale_bounds: Dict[str, Tuple[int, Optional[int]]] = \
        dataclasses.field(default_factory=dict)
    # data-type label -> (max TTFT s, max TPOT s) committed by
    # service-level intents (the planner-objective analogue)
    slo_targets: Dict[str, Tuple[Optional[float], Optional[float]]] = \
        dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class OrchestrationResult:
    policy: CompiledPolicy
    report: ValidationReport
    applied: bool
    timings: Dict[str, float]
    prompt_tokens: int
    completion_tokens: int
    # engine -> DowntimeReport, populated when submit() ran with apply_to=
    reports: Dict[str, object] = dataclasses.field(default_factory=dict)

    @property
    def success(self) -> bool:
        return self.report.passed and self.applied

    @property
    def total_s(self) -> float:
        return sum(self.timings.values())


class Orchestrator:
    def __init__(self, fabric: Optional[Fabric] = None,
                 interpreter: Optional[InterpreterBackend] = None,
                 components: Sequence[Component] = DEFAULT_WORKLOAD,
                 stabilization_s: float = 0.0):
        self.fabric = fabric or build_fabric((2, 16, 16),
                                             ("pod", "data", "model"))
        self.interpreter = interpreter or DeterministicInterpreter()
        self.components = tuple(components)
        self.state = FabricState()
        self.stabilization_s = stabilization_s
        # default placement: spread components over pods
        for i, comp in enumerate(self.components):
            self.state.placement[comp.name] = i % max(len(self.fabric.pods()), 1)

    # ------------------------------------------------------------------
    def submit(self, text: str,
               hlo_modules: Optional[Dict[str, str]] = None,
               apply_to: Optional[object] = None,
               async_reconfig: bool = False,
               ) -> OrchestrationResult:
        """Run the six-step loop for one intent.

        `apply_to` (a `repro.serving.cluster.ServingCluster` or a
        `repro.serving.autoscaler.Autoscaler` — anything with an
        ``apply_policy(policy, components=...)`` hook) extends step (F)
        into the live runtime: on a passing validation the cluster's route
        constraints are programmed from the compiled plan updates and
        affected engines are reconfigured online (compile-ahead + blocking
        swap). The per-engine `DowntimeReport`s land in `result.reports`.
        With an `Autoscaler`, the compiled per-label scaling bounds
        (``policy.scale_bounds``) are additionally pinned, so an intent
        like "keep at least two engines for phi traffic" sizes the
        cluster's elastic floor/ceiling for that label.

        With ``async_reconfig`` the runtime step rides the cluster's
        concurrent-PREPARE path: `submit` returns as soon as the intent
        is validated and the background compiles are staged, and
        `result.reports` holds per-engine `PrepareTicket`s whose
        `DowntimeReport`s finalize when the swaps commit at the cluster's
        next step boundaries (serving continues throughout).
        """
        timings: Dict[str, float] = {}

        # (A) + (B): state retrieval
        t0 = time.time()
        _topology = {"vertices": list(self.fabric.vertices),
                     "links": len(self.fabric.links)}
        _placement = dict(self.state.placement)
        timings["state_query"] = time.time() - t0

        # (C) + (D): interpretation (prompt construction inside the backend)
        t0 = time.time()
        res = self.interpreter.interpret(text, self.fabric, self.components)
        timings["interpret"] = time.time() - t0

        # compile against live state (placement first, then routing)
        t0 = time.time()
        policy = compile_intent(res.intent, self.fabric, self.components,
                                base_placement=_placement)
        timings["compile"] = time.time() - t0

        # safety layer: validate BEFORE applying (fail-closed)
        t0 = time.time()
        report = validate(policy, self.fabric, self.components,
                          hlo_modules=hlo_modules,
                          mesh_shape=self.fabric.mesh_shape,
                          axis_names=self.fabric.axis_names)
        timings["validate"] = time.time() - t0

        applied = False
        t0 = time.time()
        if report.passed:
            # (E) network flow rules, then (F) placement commit
            self.state.flows.update(policy.config.paths)
            self.state.flow_rules.extend(policy.flow_rules)
            self.state.placement.update(policy.config.placement)
            self.state.manifests.extend(policy.manifests)
            self.state.plans.update(policy.plan_updates)
            self.state.scale_bounds.update(policy.scale_bounds)
            self.state.slo_targets.update(policy.slo_targets)
            applied = True
        if self.stabilization_s:
            time.sleep(self.stabilization_s)
        timings["apply"] = time.time() - t0

        # (F, runtime) intent materialization: program the serving cluster
        reports: Dict[str, object] = {}
        if applied and apply_to is not None:
            t0 = time.time()
            kw = {"async_prepare": True} if async_reconfig else {}
            reports = apply_to.apply_policy(policy,
                                            components=self.components,
                                            **kw)
            timings["reconfigure"] = time.time() - t0

        return OrchestrationResult(
            policy=policy, report=report, applied=applied, timings=timings,
            prompt_tokens=res.prompt_tokens,
            completion_tokens=res.completion_tokens,
            reports=reports)
