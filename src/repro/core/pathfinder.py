"""Constrained path search over the labeled fabric graph.

Weighted Dijkstra with BFS fallback, exactly the paper's path scheduler
(§4.2): forbidden-vertex predicates prune the graph, waypoint constraints
decompose the search into src -> wp1 -> ... -> dst legs. Weights are
1/bandwidth so DCN hops (12.5 GB/s) cost 4x an ICI hop (50 GB/s).
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.labels import Fabric, match_labels


def _adjacency(fabric: Fabric) -> Dict[str, List[Tuple[str, float]]]:
    adj: Dict[str, List[Tuple[str, float]]] = {v: [] for v in fabric.vertices}
    for link in fabric.links:
        w = 1.0 / max(link.bw, 1.0)
        adj.setdefault(link.src, []).append((link.dst, w))
        adj.setdefault(link.dst, []).append((link.src, w))
    return adj


def _allowed(fabric: Fabric, vid: str,
             forbid: Sequence[Tuple[str, str]]) -> bool:
    labels = fabric.vertex_labels(vid)
    return not any(match_labels(labels, {k: v}) for k, v in forbid)


def dijkstra(fabric: Fabric, src: str, dst: str,
             forbid: Sequence[Tuple[str, str]] = (),
             exempt: Optional[set] = None) -> Optional[List[str]]:
    """Min-cost path avoiding forbidden vertices (exempt set excepted)."""
    if src not in fabric.vertices or dst not in fabric.vertices:
        return None
    exempt = exempt or {src, dst}
    adj = _adjacency(fabric)
    dist = {src: 0.0}
    prev: Dict[str, str] = {}
    heap = [(0.0, src)]
    seen = set()
    while heap:
        d, u = heapq.heappop(heap)
        if u in seen:
            continue
        seen.add(u)
        if u == dst:
            break
        for v, w in adj.get(u, []):
            if v not in exempt and not _allowed(fabric, v, forbid):
                continue
            nd = d + w
            if nd < dist.get(v, float("inf")):
                dist[v] = nd
                prev[v] = u
                heapq.heappush(heap, (nd, v))
    if dst not in seen:
        return None
    path = [dst]
    while path[-1] != src:
        path.append(prev[path[-1]])
    return path[::-1]


def bfs(fabric: Fabric, src: str, dst: str,
        forbid: Sequence[Tuple[str, str]] = (),
        exempt: Optional[set] = None) -> Optional[List[str]]:
    """Unweighted fallback (paper: 'weighted Dijkstra, BFS fallback')."""
    if src not in fabric.vertices or dst not in fabric.vertices:
        return None
    exempt = exempt or {src, dst}
    adj = _adjacency(fabric)
    prev: Dict[str, Optional[str]] = {src: None}
    queue = [src]
    while queue:
        u = queue.pop(0)
        if u == dst:
            break
        for v, _ in adj.get(u, []):
            if v in prev:
                continue
            if v not in exempt and not _allowed(fabric, v, forbid):
                continue
            prev[v] = u
            queue.append(v)
    if dst not in prev:
        return None
    path: List[str] = [dst]
    while prev[path[-1]] is not None:
        path.append(prev[path[-1]])  # type: ignore[arg-type]
    return path[::-1]


def find_path(fabric: Fabric, src: str, dst: str, *,
              forbid: Sequence[Tuple[str, str]] = (),
              waypoints: Sequence[str] = ()) -> Optional[List[str]]:
    """Full constrained search: src -> wp1 -> ... -> dst, Dijkstra with BFS
    fallback per leg. Endpoint attachment switches are exempt from the
    forbidden predicates (a host cannot avoid its own access switch)."""
    exempt = exempt_set(fabric, src, dst, *waypoints)

    def leg_forbid(vid_ok):
        return forbid

    legs = [src, *waypoints, dst]
    path: List[str] = [src]
    for a, b in zip(legs, legs[1:]):
        sub = (dijkstra(fabric, a, b, forbid, exempt=exempt)
               or bfs(fabric, a, b, forbid, exempt=exempt))
        if sub is None:
            return None
        path += sub[1:]
    return path


def attachment_switch(fabric: Fabric, vid: str) -> Optional[str]:
    """The access switch a host endpoint hangs off (exempt from vendor/trust
    avoidance — a host cannot avoid its own attachment)."""
    v = fabric.vertices.get(vid)
    if v is None or v.kind != "host":
        return None
    for link in fabric.links:
        if link.src == vid:
            return link.dst
        if link.dst == vid:
            return link.src
    return None


def exempt_set(fabric: Fabric, *endpoints: str) -> set:
    out = set()
    for e in endpoints:
        out.add(e)
        att = attachment_switch(fabric, e)
        if att:
            out.add(att)
    return out


def resolve_endpoint(fabric: Fabric, name: str, placement: Dict[str, int]
                     ) -> Optional[str]:
    """Map a flow endpoint (component / hostN / switch id) to a vertex id.

    Out-of-range host/switch indices resolve to None — the compiler then
    fails closed ("unknown endpoint"), catching hallucinated identifiers.
    """
    if name in fabric.vertices:
        return name
    rows = fabric.mesh_shape[fabric.axis_names.index("data")]
    if name.startswith("host"):
        try:
            n = int(name[4:])
        except ValueError:
            return None
        return f"pod0/host{n}" if n < rows else None
    # sN -> row switch N (the paper's switch naming)
    if name.startswith("s") and name[1:].isdigit():
        n = int(name[1:])
        return f"pod0/sw_r{n}" if n < rows else None
    if name == "backup":
        return f"pod0/sw_r{rows - 1}"     # role=backup switch
    # component name -> a host vertex in its pod (stable per-name index),
    # so co-located components still have distinct, routable endpoints
    if name in placement:
        idx = sum(name.encode()) % rows
        return f"pod{placement[name]}/host{idx}"
    return None
