"""The 90-intent benchmark corpus (paper §5.3).

30 computing / 30 networking / 30 hybrid; 38 simple / 52 complex (hybrid
intents predominantly complex, 28/30 — matching the paper's distribution).
Each entry carries the natural-language text plus the expected outcome:
  * "enforce"     — the validator must PASS after deployment;
  * "fail-closed" — the intent is unenforceable on this fabric (missing
    workload/label, impossible constraint) and the system must detect that
    and refuse (paper Table 6 row 1, failure-mode taxonomy §6.3).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple


@dataclasses.dataclass(frozen=True)
class CorpusEntry:
    text: str
    domain: str           # computing | networking | hybrid
    complexity: str       # simple | complex
    expect: str = "enforce"   # enforce | fail-closed


def _c(text, complexity="simple", expect="enforce"):
    return CorpusEntry(text, "computing", complexity, expect)


def _n(text, complexity="simple", expect="enforce"):
    return CorpusEntry(text, "networking", complexity, expect)


def _h(text, complexity="complex", expect="enforce"):
    return CorpusEntry(text, "hybrid", complexity, expect)


COMPUTING: List[CorpusEntry] = [
    # -- simple (20) --
    _c("Ensure all personal health data remains within the European Union."),
    _c("Place the patient service only on nodes located in london."),
    _c("Keep the phi database on high-security infrastructure."),
    _c("Deploy the appointment service in the cloud zone."),
    _c("The doctor service must run on aws nodes."),
    _c("Sensitive data must stay in the eu region."),
    _c("Run the general database on any edge node."),
    _c("Patient records must be stored on trusted infrastructure."),
    _c("Do not deploy the phi database in the edge zone."),
    _c("Never place patient data on low security nodes."),
    _c("The vital sign monitor must be hosted in the eu."),
    _c("Avoid azure nodes for the phi database."),
    _c("Host the image preprocessor in the cloud zone."),
    _c("Medical records should reside on high-security nodes."),
    _c("Schedule the appointment service on azure infrastructure."),
    _c("The general database should not run in the eu region."),
    _c("Keep health data off the edge zone."),
    _c("Protected health information must remain in london."),
    _c("Deploy the doctor service on edge nodes."),
    _c("Most sensitive data should never leave the eu."),
    # -- complex (10) --
    _c("Place phi workloads on high-security cloud nodes in the eu.",
       "complex"),
    _c("Run the patient service on aws nodes, and keep the phi database in "
       "the cloud zone.", "complex"),
    _c("Deploy the appointment service on edge nodes and ensure the general "
       "database stays on azure.", "complex"),
    _c("Sensitive health data must remain in the eu and never be scheduled "
       "on low-security nodes.", "complex"),
    _c("Keep the phi database on high-security nodes in london, and host "
       "the doctor service in the cloud zone.", "complex"),
    _c("Prohibit financial database service deployment in the cloud zone.",
       "complex", expect="fail-closed"),
    _c("Deploy the billing workload on trusted infrastructure only.",
       "complex", expect="fail-closed"),
    _c("Place the patient service and the vital sign monitor on "
       "high-security eu nodes.", "complex"),
    _c("The phi database must be on aws in the eu, and the general database "
       "must avoid the edge zone.", "complex"),
    _c("Never run patient data in china, and keep it on high-security "
       "infrastructure.", "complex"),
]

NETWORKING: List[CorpusEntry] = [
    # -- simple (16) --
    _n("Ensure that all traffic from host 2 to host 4 must traverse the "
       "backup switch s15."),
    _n("Route traffic from host 1 to host 3 avoiding huawei switches."),
    _n("Traffic from host 0 to host 5 must never cross untrusted switches."),
    _n("All packets from host 3 to host 7 must go via switch s8."),
    _n("Flows from host 2 to host 6 should avoid cisco switches."),
    _n("Traffic between host 1 and host 4 must traverse switch s5."),
    _n("Route the flow from host 0 to host 2 through switch s10."),
    _n("Packets from host 5 to host 9 must avoid untrusted switches."),
    _n("Traffic from host 4 to host 8 must not pass huawei switches."),
    _n("The flow from host 6 to host 1 must traverse the backup switch."),
    _n("Ensure traffic from host 7 to host 2 goes via switch s3."),
    _n("Route packets from host 8 to host 0 avoiding juniper switches."),
    _n("Traffic from host 9 to host 5 must traverse switch s12."),
    _n("The path from host 3 to host 1 must avoid untrusted switches."),
    _n("Flows from host 2 to host 8 must go through switch s6."),
    _n("Traffic from host 1 to host 7 must not traverse arista switches."),
    # -- complex (14) --
    _n("Traffic from host 2 to host 4 must traverse switch s8 and avoid "
       "huawei switches.", "complex"),
    _n("Route flows from host 1 to host 5 through switch s3, and never "
       "cross untrusted switches.", "complex"),
    _n("All phi traffic must stay within the pod and avoid untrusted "
       "switches.", "complex"),
    _n("Traffic from host 0 to host 6 must go via switch s4 and avoid "
       "cisco switches.", "complex"),
    _n("Packets from host 3 to host 9 must traverse switch s7 and must "
       "not pass huawei switches.", "complex"),
    _n("The flow from host 5 to host 2 must traverse the backup switch "
       "and avoid untrusted switches.", "complex"),
    _n("Route traffic from host 4 to host 1 via switch s9, avoiding "
       "juniper switches.", "complex"),
    _n("Traffic from host 6 to host 3 must traverse switch s2 and switch "
       "s11.", "complex"),
    _n("Flows from host 7 to host 0 must go through switch s13 and never "
       "cross huawei switches.", "complex"),
    _n("Traffic from host 8 to host 4 must traverse switch s1 and avoid "
       "untrusted switches.", "complex"),
    _n("Sensitive data flows must never leave the pod.", "complex"),
    _n("Phi traffic must remain inside the pod and avoid huawei "
       "switches.", "complex"),
    _n("Hosts communicating with host 4 must pass through the backup "
       "switch.", "complex"),
    _n("Traffic from host 1 to host 2 must traverse switch s99.",
       "complex", expect="fail-closed"),   # s99 does not exist -> fail closed
]

HYBRID: List[CorpusEntry] = [
    # -- simple (2) --
    _h("Keep the phi database in the eu and route its traffic through "
       "switch s5.", "simple"),
    _h("Run the patient service in the cloud zone and keep its traffic "
       "off huawei switches.", "simple"),
    # -- complex (28) --
    _h("Run appointment only on high-security cloud nodes, enforce that "
       "all other hosts communicating with host 4 must pass through the "
       "backup switch s15, and prevent sensitive databases from being "
       "deployed in the edge zone."),
    _h("Place phi workloads on eu nodes and ensure their traffic avoids "
       "untrusted switches."),
    _h("Keep patient data on high-security nodes, and route traffic from "
       "host 2 to host 5 via switch s6."),
    _h("Deploy the phi database in the cloud zone and make sure phi "
       "traffic never leaves the pod."),
    _h("Host the doctor service on aws, and traffic from host 1 to host 3 "
       "must traverse switch s4."),
    _h("Sensitive data must remain in the eu, and its flows must avoid "
       "huawei switches."),
    _h("Run the vital sign monitor on edge nodes and route its traffic "
       "through the backup switch."),
    _h("Place the general database on azure and keep traffic from host 0 "
       "to host 2 away from untrusted switches."),
    _h("Keep phi workloads in london, and phi traffic must stay within "
       "the pod."),
    _h("Deploy the appointment service on cloud nodes and route traffic "
       "from host 6 to host 1 via switch s9."),
    _h("Patient records stay on high-security eu nodes, and their traffic "
       "must avoid cisco switches."),
    _h("Run the image preprocessor on edge nodes, and traffic from host 3 "
       "to host 8 must traverse switch s2."),
    _h("The phi database must avoid the edge zone, and flows from host 4 "
       "to host 7 must go via switch s11."),
    _h("Host patient data on aws nodes in the eu and keep its traffic off "
       "untrusted switches."),
    _h("Keep the general database out of the eu, and traffic from host 5 "
       "to host 0 must traverse switch s3."),
    _h("Place the phi database on high-security nodes and route all phi "
       "traffic inside the pod avoiding huawei switches."),
    _h("Deploy the doctor service in the cloud zone, and packets from "
       "host 2 to host 9 must avoid juniper switches."),
    _h("Sensitive health data must never be deployed in china, and its "
       "traffic must avoid untrusted switches."),
    _h("Run the patient service on high-security infrastructure and "
       "traffic from host 1 to host 6 must traverse the backup switch."),
    _h("Keep the phi database in the eu region, and traffic from host 7 "
       "to host 3 must go through switch s5 avoiding huawei switches."),
    _h("Place the vital sign monitor on cloud nodes, route its traffic "
       "via switch s8, and avoid untrusted switches."),
    _h("The appointment service runs on azure edge nodes, and flows from "
       "host 0 to host 4 must traverse switch s7."),
    _h("Host phi workloads on trusted eu infrastructure, and phi flows "
       "must remain inside the pod."),
    _h("Deploy the general database in the cloud zone and route traffic "
       "from host 8 to host 2 via switch s10 avoiding cisco switches."),
    _h("Patient data must stay in the eu on high-security nodes, and its "
       "traffic must never cross untrusted switches."),
    _h("Run the financial database on eu nodes and route its traffic "
       "through switch s4.", expect="fail-closed"),
    _h("Keep the phi database on high-security cloud nodes, prevent "
       "deployment in the edge zone, and route phi traffic via the "
       "backup switch."),
    _h("Place the doctor and appointment services on cloud nodes, and "
       "traffic from host 3 to host 6 must avoid huawei switches."),
]

CORPUS: Tuple[CorpusEntry, ...] = tuple(COMPUTING + NETWORKING + HYBRID)

assert len(COMPUTING) == 30 and len(NETWORKING) == 30 and len(HYBRID) == 30
assert sum(1 for e in CORPUS if e.complexity == "simple") == 38
assert sum(1 for e in CORPUS if e.complexity == "complex") == 52
