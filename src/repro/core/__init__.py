"""The paper's primary contribution: LLM-driven intent-based privacy-aware
orchestration, realized for a multi-pod JAX fabric.

Pipeline: natural-language intent
  -> interpreter (knowledge plane, LLM-shaped backend)
  -> compiler (placement + routing -> ShardingPlans + flow paths)
  -> validator (fail-closed atomic checks incl. compiled-HLO collectives)
  -> orchestrator (six-step apply loop)
  -> reconfig (online plan swap for live serving).
"""
from repro.core.compiler import CompiledPolicy, compile_intent  # noqa: F401
from repro.core.corpus import CORPUS, CorpusEntry  # noqa: F401
from repro.core.intents import (  # noqa: F401
    Component,
    Configuration,
    DEFAULT_WORKLOAD,
    Flow,
    Intent,
    PlacementConstraint,
    RoutingConstraint,
    ScalingConstraint,
    ServiceLevelConstraint,
    satisfies,
)
from repro.core.interpreter import (  # noqa: F401
    DeterministicInterpreter,
    FaultyInterpreter,
    InterpretResult,
)
from repro.core.labels import Fabric, Site, build_fabric  # noqa: F401
from repro.core.orchestrator import FabricState, OrchestrationResult, Orchestrator  # noqa: F401
from repro.core.reconfig import DowntimeReport, ReconfigEngine  # noqa: F401
from repro.core.validator import ValidationReport, validate  # noqa: F401
