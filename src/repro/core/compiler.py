"""Intent compiler: structured constraints -> enforcement-ready configs.

The two outputs mirror the paper's orchestration plane:
  * placement directives -> a pod assignment per component + a restricted
    `ShardingPlan` (device constraints / forbidden collective axes) — the
    TPU analogue of Kubernetes node-selector manifests;
  * routing directives  -> explicit flow paths from the constrained path
    search — the analogue of ONOS per-hop flow rules.

Both are also rendered as auditable dicts (a K8s-style manifest and
ONOS-style flow rules) so the validator and the benchmark harness can
inspect exactly what would be applied.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import pathfinder
from repro.core.intents import (
    Component,
    Configuration,
    Intent,
    PlacementConstraint,
    RoutingConstraint,
    tighten_bound,
)
from repro.core.labels import Fabric, match_labels
from repro.sharding.plan import ShardingPlan


@dataclasses.dataclass
class CompiledPolicy:
    intent: Intent
    config: Configuration
    manifests: List[Dict]                    # k8s-style placement manifests
    flow_rules: List[Dict]                   # onos-style flow rules
    plan_updates: Dict[str, ShardingPlan]    # component -> restricted plan
    errors: List[str]
    # data-type label -> (min, max) serving-engine counts; consumed by
    # repro.serving.autoscaler.Autoscaler.apply_policy (max None = unbounded)
    scale_bounds: Dict[str, Tuple[int, Optional[int]]] = \
        dataclasses.field(default_factory=dict)
    # data-type label -> (max TTFT s, max TPOT s) service-level targets;
    # consumed by repro.planner.WorkloadPlanner.apply_policy (the Φ_L
    # planning objective; None = no target on that metric)
    slo_targets: Dict[str, Tuple[Optional[float], Optional[float]]] = \
        dataclasses.field(default_factory=dict)


def eligible_pods(fabric: Fabric, c: PlacementConstraint) -> List[int]:
    return [pod for pod in fabric.pods()
            if c.holds_for_site(fabric.pod_labels(pod))]


def compile_intent(
    intent: Intent,
    fabric: Fabric,
    components: Sequence[Component],
    base_placement: Optional[Dict[str, int]] = None,
    base_plan: Optional[ShardingPlan] = None,
) -> CompiledPolicy:
    """Compile an intent against live state (placement-first, then routing —
    the paper's hybrid coordination: endpoints become concrete only after
    pods are scheduled)."""
    errors: List[str] = []
    placement: Dict[str, int] = dict(base_placement or {})
    plan = base_plan or ShardingPlan()
    manifests: List[Dict] = []
    plan_updates: Dict[str, ShardingPlan] = {}
    inventory = fabric.label_inventory()

    # ---- placement (compute layer) ----
    for pc in intent.placement:
        matched = [c for c in components if c.matches(pc.sel())]
        if not matched:
            errors.append(f"unenforceable: no workload matches {pc.sel()}")
            continue
        # hallucinated-label cross-check (paper failure mode 3) — required
        # labels only; forbidding an absent label is trivially satisfied
        for k, v in pc.require:
            known = inventory.get(k, frozenset())
            if known and v not in known:
                errors.append(f"unknown label {k}={v} (not on any node)")
        pods = eligible_pods(fabric, pc)
        if not pods:
            errors.append(f"no eligible site for {pc.sel()} "
                          f"(require={dict(pc.require)} forbid={dict(pc.forbid)})")
            continue
        # secondary objective: balance load over eligible pods
        load: Dict[int, int] = {p: 0 for p in pods}
        for comp_pod in placement.values():
            if comp_pod in load:
                load[comp_pod] += 1
        for comp in matched:
            pod = min(pods, key=lambda p: load[p])
            placement[comp.name] = pod
            load[pod] += 1
            manifests.append({
                "apiVersion": "v1", "kind": "Pod",
                "metadata": {"name": comp.name, "labels": dict(comp.labels)},
                "spec": {"nodeSelector": dict(pc.require),
                         "forbiddenNodeLabels": dict(pc.forbid),
                         "assignedSite": f"pod{pod}"},
            })
            plan_updates[comp.name] = plan.with_(
                device_constraints=(("pod", pod),))

    # ---- routing (network layer) — after placement ----
    paths: Dict[Tuple[str, str], List[str]] = {}
    flow_rules: List[Dict] = []
    for rc in intent.routing:
        # pod-confinement implies co-location: move matching components into
        # one pod (hybrid coordination — placement enables routing)
        if "pod" in rc.forbidden_axes and rc.selector:
            names = [c.name for c in components if c.matches(dict(rc.selector))]
            if names:
                counts: Dict[int, int] = {}
                for nm in names:
                    p = placement.get(nm)
                    if p is not None:
                        counts[p] = counts.get(p, 0) + 1
                target = max(counts, key=counts.get) if counts else 0
                for nm in names:
                    placement[nm] = target
                    plan_updates[nm] = plan.with_(
                        device_constraints=(("pod", target),),
                        forbidden_collective_axes=tuple(rc.forbidden_axes))
        src_v = pathfinder.resolve_endpoint(fabric, rc.flow.src, placement) \
            if rc.flow.src != "*" else None
        dst_v = pathfinder.resolve_endpoint(fabric, rc.flow.dst, placement) \
            if rc.flow.dst != "*" else None
        wps = [pathfinder.resolve_endpoint(fabric, w, placement)
               for w in rc.waypoints]
        if any(w is None for w in wps):
            errors.append(f"waypoint not found: {rc.waypoints}")
            continue

        flows: List[Tuple[str, str]] = []
        if rc.flow.src == "*" and rc.flow.dst == "*":
            if rc.selector:
                # selector-scoped flows: all pairs among matching components
                names = [c.name for c in components
                         if c.matches(dict(rc.selector)) and c.name in placement]
                flows = [(a, b) for a in names for b in names if a != b]
            else:
                errors.append("ambiguous path: no src/dst and no selector "
                              "(empty <src,dst,must_go> triple)")
                continue
        elif rc.flow.src == "*":
            srcs = [c.name for c in components
                    if c.name in placement and c.name != rc.flow.dst]
            flows = [(s, rc.flow.dst) for s in srcs]
        else:
            flows = [(rc.flow.src, rc.flow.dst)]

        if src_v is None and rc.flow.src != "*":
            errors.append(f"unknown endpoint {rc.flow.src}")
            continue
        if dst_v is None and rc.flow.dst != "*":
            errors.append(f"unknown endpoint {rc.flow.dst}")
            continue

        found_any = False
        for s, d in flows:
            sv = pathfinder.resolve_endpoint(fabric, s, placement)
            dv = pathfinder.resolve_endpoint(fabric, d, placement)
            if sv is None or dv is None:
                continue
            path = pathfinder.find_path(
                fabric, sv, dv, forbid=rc.forbid_vertex,
                waypoints=[w for w in wps if w])
            if path is None:
                errors.append(f"no compliant path {s}->{d} "
                              f"(forbid={list(rc.forbid_vertex)})")
                continue
            paths[(s, d)] = path
            found_any = True
            for hop_a, hop_b in zip(path, path[1:]):
                flow_rules.append({
                    "deviceId": hop_a, "treatment": {"output": hop_b},
                    "selector": {"src": s, "dst": d,
                                 "criteria": dict(rc.selector)},
                    "priority": 40_000,
                })
        if not found_any and flows:
            errors.append(f"no applicable flow for {rc.flow} (no-op policy)")

        if rc.forbidden_axes:
            key = dict(rc.selector).get("data-type", "*")
            plan_updates[f"flows/{key}"] = plan.with_(
                forbidden_collective_axes=tuple(rc.forbidden_axes))

    # ---- scaling (runtime capacity layer) — per-label autoscaler bounds ----
    scale_bounds: Dict[str, Tuple[int, Optional[int]]] = {}
    for sc in intent.scaling:
        matched = [c for c in components if c.matches(sc.sel())]
        if not matched:
            errors.append(f"unenforceable: no workload matches {sc.sel()}")
            continue
        if sc.max_engines is not None and sc.min_engines > sc.max_engines:
            errors.append(f"inconsistent scaling bounds for {sc.sel()}: "
                          f"min {sc.min_engines} > max {sc.max_engines}")
            continue
        # bounds attach to the routing label (data-type) of the matched
        # workload class — the key the cluster routes and scales on
        values = {sc.sel().get("data-type")
                  or c.labels.get("data-type") for c in matched}
        values.discard(None)
        if not values:
            # a bound that resolves to no routing label can never be
            # enforced by the autoscaler — fail closed, don't drop it
            errors.append(f"unenforceable: scaling selector {sc.sel()} "
                          "resolves to no data-type routing label")
            continue
        for value in sorted(values):
            # several constraints can land on one label (e.g. a data-type
            # clause and an app clause whose component carries that
            # data-type): INTERSECT the bounds — last-wins would silently
            # drop an earlier clause; an empty intersection is an error
            lo, hi = scale_bounds.get(value, (0, None))
            lo = max(lo, sc.min_engines)
            if sc.max_engines is not None:
                hi = sc.max_engines if hi is None else min(hi, sc.max_engines)
            if hi is not None and lo > hi:
                errors.append(f"conflicting scaling bounds for "
                              f"data-type={value}: min {lo} > max {hi}")
                continue
            scale_bounds[value] = (lo, hi)

    # ---- service levels (runtime latency layer) — per-label SLO targets ----
    slo_targets: Dict[str, Tuple[Optional[float], Optional[float]]] = {}
    for lc in intent.service:
        matched = [c for c in components if c.matches(lc.sel())]
        if not matched:
            errors.append(f"unenforceable: no workload matches {lc.sel()}")
            continue
        if (lc.max_ttft_s is not None and lc.max_ttft_s <= 0) or \
                (lc.max_tpot_s is not None and lc.max_tpot_s <= 0):
            errors.append(f"non-positive service-level target for "
                          f"{lc.sel()}: ttft={lc.max_ttft_s} "
                          f"tpot={lc.max_tpot_s}")
            continue
        # targets attach to the routing label (data-type) of the matched
        # workload class — the key the planner sizes capacity on
        values = {lc.sel().get("data-type")
                  or c.labels.get("data-type") for c in matched}
        values.discard(None)
        if not values:
            errors.append(f"unenforceable: service-level selector "
                          f"{lc.sel()} resolves to no data-type routing "
                          "label")
            continue
        for value in sorted(values):
            # several clauses can land on one label: INTERSECT (the
            # tighter target wins — last-wins would silently relax an
            # earlier promise)
            ttft, tpot = slo_targets.get(value, (None, None))
            slo_targets[value] = (tighten_bound(ttft, lc.max_ttft_s),
                                  tighten_bound(tpot, lc.max_tpot_s))

    config = Configuration(placement=placement, paths=paths)
    return CompiledPolicy(intent=intent, config=config, manifests=manifests,
                          flow_rules=flow_rules, plan_updates=plan_updates,
                          errors=errors, scale_bounds=scale_bounds,
                          slo_targets=slo_targets)
