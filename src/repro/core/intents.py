"""Intent IR: constraints Φ_C / Φ_N and the satisfaction relation C ⊨_λ I.

Mirrors the paper's formal model (§3.3):
  * configuration C = ⟨σ, ρ⟩ — σ places workload components on sites/pods,
    ρ is the set of routing constraints realized as explicit paths;
  * C ⊨_λ I  iff  every placement constraint holds for σ under λ_N and
    every routing constraint holds for the realized paths under λ_V.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.labels import Fabric, match_labels

Labels = Mapping[str, str]


# ---------------------------------------------------------------------------
# workload model (the paper's microservice inventory, Table 3)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Component:
    """A deployable workload component (the paper's pod/service)."""

    name: str                     # "patient", "phi-db", ...
    labels: Dict[str, str]        # {"app": "patient", "data-type": "phi"}

    def matches(self, selector: Labels) -> bool:
        return match_labels(self.labels, selector)


DEFAULT_WORKLOAD = (
    Component("appointment", {"app": "appointment", "data-type": "general"}),
    Component("doctor", {"app": "doctor", "data-type": "general"}),
    Component("patient", {"app": "patient", "data-type": "phi"}),
    Component("vital-sign-monitor", {"app": "vital-sign-monitor", "data-type": "phi"}),
    Component("phi-db", {"app": "phi-db", "data-type": "phi"}),
    Component("general-db", {"app": "general-db", "data-type": "general"}),
    Component("image-preprocessor", {"app": "image-preprocessor", "data-type": "general"}),
)


@dataclasses.dataclass(frozen=True)
class Flow:
    """A traffic flow between endpoints (the paper's host pairs)."""

    src: str                      # component name or "host<N>" or "*"
    dst: str


# ---------------------------------------------------------------------------
# constraints
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlacementConstraint:
    """Φ_C: components matching `selector` must sit on sites whose labels
    satisfy `require` and none of `forbid`."""

    selector: Tuple[Tuple[str, str], ...]           # component-label predicate
    require: Tuple[Tuple[str, str], ...] = ()       # node labels that must hold
    forbid: Tuple[Tuple[str, str], ...] = ()        # node labels that must not

    def sel(self) -> Dict[str, str]:
        return dict(self.selector)

    def holds_for_site(self, site_labels: Labels) -> bool:
        if self.require and not match_labels(site_labels, dict(self.require)):
            return False
        for k, v in self.forbid:
            if match_labels(site_labels, {k: v}):
                return False
        return True


@dataclasses.dataclass(frozen=True)
class RoutingConstraint:
    """Φ_N: paths for `flow` must avoid forbidden vertices, include the
    required waypoints, and (TPU realization) never cross forbidden mesh
    axes with the selected tensors' collectives."""

    flow: Flow
    forbid_vertex: Tuple[Tuple[str, str], ...] = ()   # λ_V predicates to avoid
    waypoints: Tuple[str, ...] = ()                   # vertex ids that must appear
    forbidden_axes: Tuple[str, ...] = ()              # mesh axes (e.g. ("pod",))
    selector: Tuple[Tuple[str, str], ...] = ()        # data selector (phi flows)


@dataclasses.dataclass(frozen=True)
class ScalingConstraint:
    """Φ_S (runtime extension): the serving fabric must keep between
    `min_engines` and `max_engines` engines able to serve the workload
    class matching `selector` ("keep at least two engines for phi
    traffic"). Compiled into per-label autoscaler bounds
    (`CompiledPolicy.scale_bounds`) and enforced by
    `repro.serving.autoscaler.Autoscaler`."""

    selector: Tuple[Tuple[str, str], ...]     # component-label predicate
    min_engines: int = 0
    max_engines: Optional[int] = None         # None == unbounded

    def sel(self) -> Dict[str, str]:
        return dict(self.selector)


def tighten_bound(old: Optional[float], new: Optional[float]
                  ) -> Optional[float]:
    """Intersection of two optional upper bounds (the tighter wins) —
    the merge rule for repeated service-level targets on one label,
    shared by the compiler and the planner."""
    if old is None:
        return new
    if new is None:
        return old
    return min(old, new)


@dataclasses.dataclass(frozen=True)
class ServiceLevelConstraint:
    """Φ_L (runtime extension): the serving fabric must keep the latency
    of the workload class matching `selector` within the given targets
    ("keep TTFT under 200 ms for phi traffic"). Compiled into per-label
    planner objectives (`CompiledPolicy.slo_targets`) and enforced by
    `repro.planner.WorkloadPlanner`, which sizes and places capacity so
    the cost-model-predicted TTFT/TPOT stay inside the targets."""

    selector: Tuple[Tuple[str, str], ...]     # component-label predicate
    max_ttft_s: Optional[float] = None        # time-to-first-token target
    max_tpot_s: Optional[float] = None        # per-output-token target

    def sel(self) -> Dict[str, str]:
        return dict(self.selector)


@dataclasses.dataclass(frozen=True)
class Intent:
    text: str
    domain: str                   # computing | networking | hybrid
    complexity: str               # simple | complex
    placement: Tuple[PlacementConstraint, ...] = ()
    routing: Tuple[RoutingConstraint, ...] = ()
    scaling: Tuple[ScalingConstraint, ...] = ()
    service: Tuple[ServiceLevelConstraint, ...] = ()
    # intents referencing labels absent from the fabric are *unenforceable*
    # and must fail closed (paper Table 6, row 1)
    expect_unenforceable: bool = False


# ---------------------------------------------------------------------------
# configuration (C = ⟨σ, ρ⟩) and satisfaction
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Configuration:
    """A deployed configuration: placement map + realized flow paths."""

    placement: Dict[str, int]                 # component name -> pod index
    paths: Dict[Tuple[str, str], List[str]]   # (src, dst) -> vertex-id path
    plans: Dict[str, object] = dataclasses.field(default_factory=dict)
    # executables etc. attached by the orchestrator


def placement_satisfied(c: PlacementConstraint, config: Configuration,
                        fabric: Fabric, components: Sequence[Component]
                        ) -> Tuple[bool, str]:
    matched = [comp for comp in components if comp.matches(c.sel())]
    if not matched:
        return False, f"no component matches selector {c.sel()} (unenforceable)"
    for comp in matched:
        pod = config.placement.get(comp.name)
        if pod is None:
            return False, f"component {comp.name} not placed"
        labels = fabric.pod_labels(pod)
        if not c.holds_for_site(labels):
            return False, (f"{comp.name} on pod{pod} {labels} violates "
                           f"require={dict(c.require)} forbid={dict(c.forbid)}")
    return True, f"{len(matched)} component(s) compliant"


def routing_satisfied(c: RoutingConstraint, config: Configuration,
                      fabric: Fabric) -> Tuple[bool, str]:
    from repro.core import pathfinder  # local import (no cycle at module load)

    flows = [(s, d) for (s, d) in config.paths
             if _flow_matches(c.flow, s, d)]
    if not flows:
        return False, f"no realized flow matches {c.flow} (no-op policy)"
    for key in flows:
        path = config.paths[key]
        exempt = pathfinder.exempt_set(fabric, path[0], path[-1])
        # explicitly named waypoints override avoidance predicates
        for wp in c.waypoints:
            wp_v = pathfinder.resolve_endpoint(fabric, wp, config.placement)
            if wp_v:
                exempt.add(wp_v)
        for vid in path:
            if vid in exempt:
                continue
            labels = fabric.vertex_labels(vid)
            for k, v in c.forbid_vertex:
                if match_labels(labels, {k: v}):
                    return False, f"path {key} traverses forbidden {vid} ({k}={v})"
        for wp in c.waypoints:
            wp_v = pathfinder.resolve_endpoint(fabric, wp, config.placement)
            if wp_v is None or wp_v not in path:
                return False, f"path {key} misses waypoint {wp}"
        if "pod" in c.forbidden_axes:
            pods = {fabric.vertex_labels(v).get("pod") for v in path}
            if len(pods) > 1:
                return False, f"path {key} crosses pods {sorted(pods)}"
    return True, f"{len(flows)} flow(s) compliant"


def _flow_matches(flow: Flow, src: str, dst: str) -> bool:
    return (flow.src in ("*", src)) and (flow.dst in ("*", dst))


def satisfies(intent: Intent, config: Configuration, fabric: Fabric,
              components: Sequence[Component]) -> Tuple[bool, List[str]]:
    """C ⊨_λ I — returns (ok, list of per-constraint messages)."""
    msgs: List[str] = []
    ok = True
    for pc in intent.placement:
        good, msg = placement_satisfied(pc, config, fabric, components)
        ok &= good
        msgs.append(("PASS " if good else "FAIL ") + msg)
    for rc in intent.routing:
        good, msg = routing_satisfied(rc, config, fabric)
        ok &= good
        msgs.append(("PASS " if good else "FAIL ") + msg)
    return ok, msgs
