"""Post-compile HLO analysis: collective extraction + mesh-axis attribution.

This is the shared substrate of two consumers:

  * the ROOFLINE harness — sums per-device wire bytes of every collective
    in the compiled module (cost_analysis does not report collectives);
  * the INTENT VALIDATOR (repro.core.validator) — the paper's
    "post-deployment compliance check" realized at the XLA level: every
    collective's replica groups are mapped back to mesh axes, so routing
    constraints ("PHI tensors' traffic must not cross the pod axis") are
    checked against the *compiled artifact*, which covers every step the
    executable will ever run (stronger than the paper's runtime sampling).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

COLLECTIVE_KINDS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "collective-broadcast",
    "ragged-all-to-all",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{(\{[^=]*?\})\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
_PAIRS_RE = re.compile(r"source_target_pairs=\{([^}]*(?:\},\{[^}]*)*)\}")


def _shape_bytes(dtype: str, dims_str: str) -> int:
    n = 1
    if dims_str.strip():
        for d in dims_str.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class Collective:
    kind: str
    result_bytes: int            # total bytes of the result shape(s)
    operand_bytes: int           # total bytes of operand shape(s)
    group_size: int              # devices per replica group (0 if unknown)
    groups: Optional[np.ndarray]  # (num_groups, group_size) device ids
    pairs: Optional[List[Tuple[int, int]]]  # collective-permute
    line: str

    def wire_bytes_per_device(self) -> float:
        """Ring-model bytes each device moves over links for this op."""
        n = max(self.group_size, 1)
        frac = (n - 1) / n if n > 1 else 0.0
        if self.kind == "all-gather":
            return self.result_bytes * frac   # (n-1) shards of out/n each
        if self.kind == "reduce-scatter":
            return self.operand_bytes * frac
        if self.kind == "all-reduce":
            return 2.0 * self.operand_bytes * frac
        if self.kind in ("all-to-all", "ragged-all-to-all"):
            return self.operand_bytes * frac
        if self.kind in ("collective-permute", "collective-broadcast"):
            return float(self.operand_bytes)
        return float(self.operand_bytes)


def _parse_groups_explicit(s: str) -> np.ndarray:
    groups = []
    for grp in re.findall(r"\{([0-9,\s]*)\}", s):
        ids = [int(t) for t in grp.replace(" ", "").split(",") if t]
        if ids:
            groups.append(ids)
    width = max(len(g) for g in groups) if groups else 0
    return np.asarray([g + [-1] * (width - len(g)) for g in groups], dtype=np.int64)


def _parse_groups_iota(m: re.Match) -> np.ndarray:
    g, s = int(m.group(1)), int(m.group(2))
    src = [int(t) for t in m.group(3).split(",")]
    arr = np.arange(int(np.prod(src)), dtype=np.int64).reshape(src)
    if m.group(4):
        perm = [int(t) for t in m.group(4).split(",")]
        arr = arr.transpose(perm)
    return arr.reshape(g, s)


def parse_collectives(hlo_text: str) -> List[Collective]:
    out: List[Collective] = []
    for line in hlo_text.splitlines():
        stripped = line.strip()
        kind = None
        for k in COLLECTIVE_KINDS:
            # match as the op name: " = <shape> <kind>(" or "<kind>-start("
            if f" {k}(" in stripped or f" {k}-start(" in stripped:
                kind = k
                break
        if kind is None:
            continue
        if stripped.startswith("ROOT"):
            stripped = stripped[4:].strip()
        # split into result part and operand part at the op name
        idx = stripped.find(f" {kind}")
        result_part = stripped[:idx]
        operand_part = stripped[idx:]
        res_shapes = _SHAPE_RE.findall(result_part)
        op_shapes = _SHAPE_RE.findall(operand_part.split("),", 1)[0]
                                      if ")," in operand_part else operand_part)
        result_bytes = sum(_shape_bytes(d, s) for d, s in res_shapes)
        operand_bytes = sum(_shape_bytes(d, s) for d, s in op_shapes) or result_bytes

        groups = None
        m = _GROUPS_IOTA_RE.search(stripped)
        if m:
            groups = _parse_groups_iota(m)
        else:
            m2 = _GROUPS_EXPLICIT_RE.search(stripped)
            if m2:
                groups = _parse_groups_explicit(m2.group(0)[len("replica_groups="):])

        pairs = None
        mp = _PAIRS_RE.search(stripped)
        if mp:
            nums = [int(t) for t in re.findall(r"\d+", mp.group(1))]
            pairs = list(zip(nums[0::2], nums[1::2]))

        gsize = int(groups.shape[1]) if groups is not None and groups.ndim == 2 else (
            2 if pairs else 0)
        out.append(Collective(kind, result_bytes, operand_bytes, gsize,
                              groups, pairs, stripped[:400]))
    return out


# ---------------------------------------------------------------------------
# mesh-axis attribution
# ---------------------------------------------------------------------------


def axes_crossed(
    groups: Optional[np.ndarray],
    pairs: Optional[List[Tuple[int, int]]],
    mesh_shape: Sequence[int],
    axis_names: Sequence[str],
) -> Tuple[str, ...]:
    """Mesh axes along which this collective moves data."""
    shape = tuple(mesh_shape)
    crossed: set = set()

    def coords(ids: np.ndarray) -> np.ndarray:
        return np.stack(np.unravel_index(ids, shape), axis=-1)  # (..., naxes)

    if groups is not None:
        for grp in groups:
            ids = grp[grp >= 0]
            if len(ids) < 2:
                continue
            c = coords(ids)
            for ax in range(len(shape)):
                if len(np.unique(c[:, ax])) > 1:
                    crossed.add(axis_names[ax])
    if pairs:
        arr = np.asarray(pairs, dtype=np.int64)
        src, dst = coords(arr[:, 0]), coords(arr[:, 1])
        for ax in range(len(shape)):
            if np.any(src[:, ax] != dst[:, ax]):
                crossed.add(axis_names[ax])
    return tuple(sorted(crossed))


def collective_summary(
    hlo_text: str,
    mesh_shape: Sequence[int],
    axis_names: Sequence[str],
) -> Dict:
    """Aggregate: per-kind counts/bytes and per-axis wire bytes."""
    colls = parse_collectives(hlo_text)
    by_kind: Dict[str, Dict[str, float]] = {}
    by_axis: Dict[str, float] = {a: 0.0 for a in axis_names}
    total_wire = 0.0
    for c in colls:
        e = by_kind.setdefault(c.kind, {"count": 0, "wire_bytes": 0.0,
                                        "result_bytes": 0})
        wb = c.wire_bytes_per_device()
        e["count"] += 1
        e["wire_bytes"] += wb
        e["result_bytes"] += c.result_bytes
        total_wire += wb
        axes = axes_crossed(c.groups, c.pairs, mesh_shape, axis_names)
        for a in axes:
            by_axis[a] += wb / max(len(axes), 1)
    return {
        "n_collectives": len(colls),
        "by_kind": by_kind,
        "wire_bytes_by_axis": by_axis,
        "total_wire_bytes_per_device": total_wire,
        "collectives": colls,
    }
