"""Trip-count-aware HLO cost model.

XLA's `compiled.cost_analysis()` counts each `while` body ONCE regardless of
its trip count (verified empirically on the CPU backend), which makes it
useless for scan-over-layers models: a 96-layer stack reports one layer of
FLOPs. This module re-derives FLOPs / bytes-accessed / collective wire
bytes by walking the post-optimization HLO text, recursing through
called computations (fusions, while bodies, conditionals) and multiplying
by `known_trip_count` from each while op's backend_config.

Cost conventions:
  * dot: 2 x prod(result_shape) x prod(contracting dims of lhs)
  * convolution: 2 x prod(result_shape) x (kernel elements / output features)
  * transcendental elementwise (exp/log/tanh/...): result elements (x1)
  * other elementwise: result elements
  * bytes accessed: operand bytes + result bytes at fusion/op boundaries
    (inside-fusion ops contribute flops only — matching XLA's convention)
  * collectives: recorded with their execution multiplier for the roofline
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.hlo_analysis import (
    Collective,
    _DTYPE_BYTES,
    _GROUPS_EXPLICIT_RE,
    _GROUPS_IOTA_RE,
    _PAIRS_RE,
    _parse_groups_explicit,
    _parse_groups_iota,
    COLLECTIVE_KINDS,
)

_SHAPE_TOK = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->\s*(.+?)\s*\{")
_CALLS_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'"?known_trip_count"?[=:]\s*\{"n":"(\d+)"\}')
_OPNAME_RE = re.compile(r"^([a-z][a-z0-9\-]*)\(")

_TRANSCENDENTAL = {
    "exponential", "log", "tanh", "rsqrt", "sqrt", "power", "divide",
    "logistic", "sine", "cosine", "atan2", "exponential-minus-one",
    "log-plus-one", "erf", "cbrt",
}
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "iota", "partition-id", "replica-id", "reshape",
    "broadcast", "copy", "transpose", "slice", "concatenate", "pad",
    "dynamic-slice", "dynamic-update-slice", "reverse", "convert",
    "reduce", "select", "compare", "and", "or", "not", "xor", "copy-start",
    "copy-done",
}


def _shapes_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_TOK.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(text: str) -> int:
    m = _SHAPE_TOK.search(text)
    if not m:
        return 0
    n = 1
    if m.group(2):
        for d in m.group(2).split(","):
            n *= int(d)
    return n


@dataclasses.dataclass
class OpInfo:
    name: str
    kind: str
    result_type: str          # full result type text
    rest: str                 # everything after the op name


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]    # param name -> type text
    ops: List[OpInfo]


def parse_module(hlo_text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR.match(line.strip())
        if hdr and ("=" not in line.split("(")[0]):
            name = hdr.group(2)
            params: Dict[str, str] = {}
            # params like "arg.1: f32[8,512], p2: (f32[...], s32[])"
            ptxt = hdr.group(3)
            for pm in re.finditer(r"([\w\.\-]+):\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)", ptxt):
                params[pm.group(1)] = pm.group(2)
            cur = Computation(name, params, [])
            comps[name] = cur
            if hdr.group(1):
                entry = name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # rhs = "<type> opname(...)..." — type may be a tuple
        om = re.search(r"\)?\s*([a-z][a-z0-9\-]*)\(", rhs)
        if not om:
            continue
        kind = om.group(1)
        result_type = rhs[: om.start()].strip()
        rest = rhs[om.start():]
        cur.ops.append(OpInfo(name, kind, result_type, rest))
    return comps, entry


@dataclasses.dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    collectives: List[Tuple[Collective, float]] = dataclasses.field(default_factory=list)

    def add(self, other: "CostTotals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.transcendentals += other.transcendentals * mult
        for c, m in other.collectives:
            self.collectives.append((c, m * mult))


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps, self.entry = parse_module(hlo_text)
        self._memo: Dict[Tuple[str, bool], CostTotals] = {}

    # -- shape lookup ------------------------------------------------------
    def _symbol_types(self, comp: Computation) -> Dict[str, str]:
        table = dict(comp.params)
        for op in comp.ops:
            table[op.name] = op.result_type
        return table

    def _operand_names(self, rest: str) -> List[str]:
        # operands are inside the first (...) after op name
        depth = 0
        start = rest.find("(")
        out = []
        buf = ""
        for ch in rest[start:]:
            if ch == "(":
                depth += 1
                if depth == 1:
                    continue
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    if buf.strip():
                        out.append(buf.strip())
                    break
            if depth >= 1:
                if ch == "," and depth == 1:
                    out.append(buf.strip())
                    buf = ""
                else:
                    buf += ch
        names = []
        for o in out:
            mm = re.search(r"%([\w\.\-]+)\s*$", o)
            if mm:
                names.append(mm.group(1))
        return names

    def _dot_flops(self, comp: Computation, op: OpInfo, table: Dict[str, str]) -> float:
        res_elems = _shape_elems(op.result_type)
        mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
        contract = 1
        operands = self._operand_names(op.rest)
        if mc and operands:
            lhs_type = table.get(operands[0], "")
            sm = _SHAPE_TOK.search(lhs_type)
            if sm and sm.group(2):
                dims = [int(d) for d in sm.group(2).split(",")]
                for ci in (int(x) for x in mc.group(1).split(",") if x):
                    if ci < len(dims):
                        contract *= dims[ci]
        return 2.0 * res_elems * contract

    def _conv_flops(self, comp: Computation, op: OpInfo, table: Dict[str, str]) -> float:
        res_elems = _shape_elems(op.result_type)
        operands = self._operand_names(op.rest)
        kernel_elems = 0
        out_feats = 1
        if len(operands) >= 2:
            kt = table.get(operands[1], "")
            sm = _SHAPE_TOK.search(kt)
            if sm and sm.group(2):
                dims = [int(d) for d in sm.group(2).split(",")]
                kernel_elems = int(np.prod(dims))
                out_feats = dims[-1] if dims else 1
        if not kernel_elems:
            return 2.0 * res_elems
        return 2.0 * res_elems * (kernel_elems / max(out_feats, 1))

    def _collective(self, op: OpInfo, table: Dict[str, str]) -> Collective:
        result_bytes = _shapes_bytes(op.result_type)
        operands = self._operand_names(op.rest)
        operand_bytes = sum(_shapes_bytes(table.get(o, "")) for o in operands) or result_bytes
        groups = None
        m = _GROUPS_IOTA_RE.search(op.rest)
        if m:
            groups = _parse_groups_iota(m)
        else:
            m2 = _GROUPS_EXPLICIT_RE.search(op.rest)
            if m2:
                groups = _parse_groups_explicit(m2.group(0)[len("replica_groups="):])
        pairs = None
        mp = _PAIRS_RE.search(op.rest)
        if mp:
            nums = [int(t) for t in re.findall(r"\d+", mp.group(1))]
            pairs = list(zip(nums[0::2], nums[1::2]))
        gsize = int(groups.shape[1]) if groups is not None and groups.ndim == 2 else (
            2 if pairs else 0)
        kind = op.kind[:-6] if op.kind.endswith("-start") else op.kind
        return Collective(kind, result_bytes, operand_bytes, gsize, groups,
                          pairs, (op.result_type + " " + op.rest)[:400])

    # -- main recursion ----------------------------------------------------
    def cost(self, comp_name: Optional[str] = None, *, in_fusion: bool = False) -> CostTotals:
        comp_name = comp_name or self.entry
        key = (comp_name, in_fusion)
        if key in self._memo:
            return self._memo[key]
        total = CostTotals()
        comp = self.comps.get(comp_name)
        if comp is None:
            self._memo[key] = total
            return total
        table = self._symbol_types(comp)
        for op in comp.ops:
            kind = op.kind
            base_kind = kind[:-6] if kind.endswith("-start") else kind
            if kind.endswith("-done"):
                continue
            if base_kind in COLLECTIVE_KINDS:
                c = self._collective(op, table)
                total.collectives.append((c, 1.0))
                if not in_fusion:
                    total.bytes += c.operand_bytes + c.result_bytes
                continue
            if kind == "fusion":
                mcalls = _CALLS_RE.search(op.rest)
                if mcalls:
                    total.add(self.cost(mcalls.group(1), in_fusion=True))
                if not in_fusion:
                    total.bytes += self._boundary_bytes(op, table)
                continue
            if kind == "while":
                body = re.search(r"body=%?([\w\.\-]+)", op.rest)
                cond = re.search(r"condition=%?([\w\.\-]+)", op.rest)
                mt = _TRIP_RE.search(op.rest)
                trips = float(mt.group(1)) if mt else 1.0
                if body:
                    total.add(self.cost(body.group(1), in_fusion=in_fusion), trips)
                if cond:
                    total.add(self.cost(cond.group(1), in_fusion=in_fusion), trips)
                continue
            if kind in ("call", "conditional", "async-start"):
                for cm in _CALLS_RE.finditer(op.rest):
                    total.add(self.cost(cm.group(1), in_fusion=in_fusion))
                # also branch computations listed as {%a, %b}
                bm = re.search(r"branch_computations=\{([^}]*)\}", op.rest)
                if bm:
                    for nm in re.findall(r"%([\w\.\-]+)", bm.group(1)):
                        total.add(self.cost(nm, in_fusion=in_fusion))
                continue
            if kind == "dot":
                total.flops += self._dot_flops(comp, op, table)
            elif kind == "convolution":
                total.flops += self._conv_flops(comp, op, table)
            elif kind in _FREE_OPS:
                pass
            else:
                elems = _shape_elems(op.result_type)
                total.flops += elems
                if kind in _TRANSCENDENTAL:
                    total.transcendentals += elems
            if not in_fusion and kind not in ("parameter", "constant",
                                              "get-tuple-element", "tuple"):
                total.bytes += self._boundary_bytes(op, table)
        self._memo[key] = total
        return total

    def _boundary_bytes(self, op: OpInfo, table: Dict[str, str]) -> float:
        """Bytes moved at an (un-fused) op boundary.

        dynamic-slice reads only its slice; dynamic-update-slice touches only
        the updated region — counting their full operand/result buffers would
        overcount scan-stacked weights by O(num_layers).
        """
        result_bytes = _shapes_bytes(op.result_type)
        # fusion NAMES use snake_case, op kinds use kebab-case — match both
        tag = (op.name + " " + op.rest[:80]).replace("_", "-")
        if "dynamic-update-slice" in tag:
            operands = self._operand_names(op.rest)
            sizes = sorted(b for b in (_shapes_bytes(table.get(o, ""))
                                       for o in operands) if b > 0)
            update = sizes[0] if sizes else result_bytes
            return 2.0 * min(update, result_bytes)
        if "dynamic-slice" in tag:
            return 2.0 * result_bytes
        operands = self._operand_names(op.rest)
        return (sum(_shapes_bytes(table.get(o, "")) for o in operands)
                + result_bytes)


def analyze(hlo_text: str, mesh_shape, axis_names) -> Dict:
    """Full roofline-input analysis of a compiled SPMD module (per device)."""
    from repro.core.hlo_analysis import axes_crossed

    model = HloCostModel(hlo_text)
    totals = model.cost()
    by_kind: Dict[str, Dict[str, float]] = {}
    by_axis: Dict[str, float] = {a: 0.0 for a in axis_names}
    wire = 0.0
    for c, mult in totals.collectives:
        e = by_kind.setdefault(c.kind, {"count": 0.0, "wire_bytes": 0.0})
        wb = c.wire_bytes_per_device() * mult
        e["count"] += mult
        e["wire_bytes"] += wb
        wire += wb
        axes = axes_crossed(c.groups, c.pairs, mesh_shape, axis_names)
        for a in axes:
            by_axis[a] += wb / max(len(axes), 1)
    return {
        "flops": totals.flops,
        "bytes": totals.bytes,
        "transcendentals": totals.transcendentals,
        "n_collective_ops": len(totals.collectives),
        "collectives_by_kind": by_kind,
        "wire_bytes_by_axis": by_axis,
        "wire_bytes_per_device": wire,
        "_collectives": totals.collectives,
    }
