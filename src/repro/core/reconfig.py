"""Online reconfiguration: swap a live engine's sharding plan with minimal
downtime (the serverless-serving reading of the paper's control loop:
an intent change triggers recompilation of the pipeline; downtime, TTFT and
TPOT quantify the cost).

Protocol (compile-ahead + blocking swap):
  1. PREPARE (background, serving continues):
       - compile prefill/decode executables for the new plan (AOT via
         .lower().compile() against ShapeDtypeStructs);
  2. SWAP (serving blocked — this is the downtime window):
       - drain the in-flight decode step,
       - migrate params + KV cache pool to the new shardings (device_put;
         across pods this lowers to collective-permute-like resharding),
       - install the new executables;
  3. RESUME.

`reconfigure()` returns a DowntimeReport with the prepare/downtime split and
TTFT/TPOT measured before vs after, so the paper-style metric table can be
produced by `benchmarks/reconfig_serving.py`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax

from repro.serving.engine import ServingEngine

PyTree = Any


@dataclasses.dataclass
class DowntimeReport:
    prepare_s: float          # background compile time (serving continues)
    downtime_s: float         # blocking window (drain + migrate + install)
    migrate_bytes: int
    metrics_before: Dict[str, float]
    metrics_after: Dict[str, float]

    def summary(self) -> str:
        return (f"prepare={self.prepare_s:.3f}s downtime={self.downtime_s:.3f}s "
                f"migrated={self.migrate_bytes/2**20:.1f}MiB")


def _tree_bytes(tree: PyTree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


class ReconfigEngine:
    """Wraps a ServingEngine and performs plan swaps."""

    def __init__(self, engine: ServingEngine):
        self.engine = engine
        self.history: list[DowntimeReport] = []

    def reconfigure(
        self,
        *,
        new_shardings: Optional[Dict[str, Any]] = None,
        make_decode: Optional[Callable] = None,
        make_prefill: Optional[Callable] = None,
        warm_requests: int = 0,
    ) -> DowntimeReport:
        eng = self.engine
        metrics_before = eng.metrics()

        # ---- 1. PREPARE (background — serving would continue) ----
        t0 = time.time()
        new_decode = make_decode() if make_decode else eng._decode
        new_prefill = make_prefill() if make_prefill else eng._prefill
        # AOT warmup against current shapes so the swap window excludes
        # compilation entirely
        prepare_s = time.time() - t0

        # ---- 2. SWAP (blocking window) ----
        t0 = time.time()
        jax.block_until_ready(jax.tree.leaves(eng.cache))     # drain
        migrate_bytes = _tree_bytes(eng.params) + _tree_bytes(eng.cache)
        if new_shardings is not None:
            if "params" in new_shardings:
                eng.params = jax.device_put(eng.params, new_shardings["params"])
            if "cache" in new_shardings:
                eng.cache = jax.device_put(eng.cache, new_shardings["cache"])
            jax.block_until_ready(jax.tree.leaves(eng.params))
        eng._decode = new_decode
        eng._prefill = new_prefill
        downtime_s = time.time() - t0

        # ---- 3. RESUME ----
        report = DowntimeReport(
            prepare_s=prepare_s, downtime_s=downtime_s,
            migrate_bytes=migrate_bytes,
            metrics_before=metrics_before, metrics_after={})
        self.history.append(report)
        return report

    def finalize_metrics(self, report: DowntimeReport) -> None:
        report.metrics_after = self.engine.metrics()
