"""Online reconfiguration — DEPRECATED single-engine shim.

The reconfiguration protocol (compile-ahead + blocking swap, DowntimeReport
with prepare/downtime split and TTFT/TPOT before vs after) now lives in the
cluster runtime: `repro.serving.cluster.ServingCluster.reconfigure()`, which
AOT-compiles in PREPARE, drives the engine's public
pause()/drain()/swap_plan()/resume() lifecycle, and finalizes the report's
metrics automatically. `benchmarks/reconfig_serving.py` produces the
paper-style metric table from it.

`ReconfigEngine` is kept so pre-cluster callers keep working; it delegates
to the same engine lifecycle (no private-attribute mutation) and emits a
DeprecationWarning. New code should use:

    cluster = ServingCluster()
    cluster.register("e0", engine)
    report = cluster.reconfigure("e0", new_plan)
"""
from __future__ import annotations

import time
import warnings
from typing import Any, Callable, Dict, Optional

import jax

from repro.serving.cluster import DowntimeReport  # noqa: F401  (re-export)
from repro.serving.engine import ServingEngine

PyTree = Any


class ReconfigEngine:
    """DEPRECATED: wraps a single ServingEngine and performs plan swaps.

    Use `ServingCluster.reconfigure` instead — it materializes shardings
    from a `ShardingPlan`, performs real AOT compilation in PREPARE, and
    auto-finalizes the report."""

    def __init__(self, engine: ServingEngine):
        warnings.warn(
            "ReconfigEngine is deprecated; use ServingCluster.reconfigure",
            DeprecationWarning, stacklevel=2)
        self.engine = engine
        self.history: list[DowntimeReport] = []

    def reconfigure(
        self,
        *,
        new_shardings: Optional[Dict[str, Any]] = None,
        make_decode: Optional[Callable] = None,
        make_prefill: Optional[Callable] = None,
        warm_requests: int = 0,
    ) -> DowntimeReport:
        eng = self.engine
        metrics_before = eng.metrics()

        # ---- 1. PREPARE (background — serving would continue) ----
        t0 = time.time()
        executables: Dict[str, Any] = {}
        if make_decode:
            executables["decode"] = make_decode()
        if make_prefill:
            executables["prefill"] = make_prefill()
        prepare_s = time.time() - t0

        # ---- 2. SWAP (blocking window, via the public lifecycle) ----
        t0 = time.time()
        eng.pause()
        eng.drain()
        migrate_bytes = eng.swap_plan(shardings=new_shardings,
                                      executables=executables)
        eng.resume()
        downtime_s = time.time() - t0

        # ---- 3. RESUME (metrics_after auto-finalized; finalize_metrics
        #         refreshes it after more traffic, for old callers) ----
        report = DowntimeReport(
            prepare_s=prepare_s, downtime_s=downtime_s,
            migrate_bytes=migrate_bytes,
            metrics_before=metrics_before, metrics_after=eng.metrics())
        self.history.append(report)
        return report

    def finalize_metrics(self, report: DowntimeReport) -> None:
        report.metrics_after = self.engine.metrics()
