"""Automated validation pipeline (paper §5.5): atomic pass/fail checks over
the post-deployment state, executed without human intervention.

Check classes:
  * placement checks — every matched component sits on a site satisfying
    the constraint (λ_N lookup);
  * label-existence checks — referenced labels exist in the inventory
    (catches hallucinated identifiers, failure mode 3);
  * routing checks — realized paths avoid forbidden vertices / include
    waypoints; a constraint that matched no flow is a detected no-op
    policy (failure mode 2) and FAILS;
  * HLO checks — for plans carrying `forbidden_collective_axes`, the
    compiled executable's collectives must not cross those mesh axes
    (parsed from the SPMD module; stronger than runtime sampling since
    compile-time proof covers every step);
  * scaling checks — autoscaler bounds must target an existing workload
    class and be internally consistent (min <= max).

An intent is successful only if ALL its checks pass (fail-closed).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import hlo_cost
from repro.core.compiler import CompiledPolicy
from repro.core.hlo_analysis import axes_crossed
from repro.core.intents import (
    Component,
    Configuration,
    Intent,
    placement_satisfied,
    routing_satisfied,
)
from repro.core.labels import Fabric


@dataclasses.dataclass
class Check:
    name: str
    passed: bool
    detail: str


@dataclasses.dataclass
class ValidationReport:
    intent_text: str
    checks: List[Check]
    elapsed_s: float

    @property
    def passed(self) -> bool:
        return bool(self.checks) and all(c.passed for c in self.checks)

    @property
    def n_checks(self) -> int:
        return len(self.checks)

    def summary(self) -> str:
        flag = "PASS" if self.passed else "FAIL"
        return f"[{flag}] {len(self.checks)} checks, {self.elapsed_s*1e3:.1f} ms"


def validate(policy: CompiledPolicy, fabric: Fabric,
             components: Sequence[Component],
             hlo_modules: Optional[Dict[str, str]] = None,
             mesh_shape: Optional[Tuple[int, ...]] = None,
             axis_names: Optional[Tuple[str, ...]] = None) -> ValidationReport:
    t0 = time.time()
    intent = policy.intent
    config = policy.config
    checks: List[Check] = []
    inventory = fabric.label_inventory()

    # compiler-detected errors fail closed
    for err in policy.errors:
        checks.append(Check("compiler/fail-closed", False, err))

    # ---- placement checks ----
    for i, pc in enumerate(intent.placement):
        # hallucination cross-check applies to REQUIRED labels only: a
        # forbid on an absent label is trivially satisfied, not an error
        for k, v in pc.require:
            known = inventory.get(k, frozenset())
            ok = (not known) or (v in known)
            checks.append(Check(
                f"placement[{i}]/label-exists({k}={v})", ok,
                "label present in inventory" if ok
                else f"label {k}={v} does not exist on any node"))
        ok, msg = placement_satisfied(pc, config, fabric, components)
        checks.append(Check(f"placement[{i}]/state", ok, msg))

    # ---- routing checks ----
    for i, rc in enumerate(intent.routing):
        if rc.waypoints or rc.forbid_vertex or rc.forbidden_axes \
                or rc.flow.src != "*" or rc.flow.dst != "*":
            ok, msg = routing_satisfied(rc, config, fabric)
            checks.append(Check(f"routing[{i}]/paths", ok, msg))
        # HLO-level collective-axis compliance
        if rc.forbidden_axes and hlo_modules is not None:
            key = dict(rc.selector).get("data-type", "*")
            for mod_name, hlo in hlo_modules.items():
                if key != "*" and key not in mod_name:
                    continue
                ok, msg = check_hlo_axes(hlo, rc.forbidden_axes,
                                         mesh_shape or (2, 16, 16),
                                         axis_names or ("pod", "data", "model"))
                checks.append(Check(
                    f"routing[{i}]/hlo-collectives[{mod_name}]", ok, msg))

    # ---- scaling checks (runtime capacity bounds) ----
    for i, sc in enumerate(intent.scaling):
        matched = [c for c in components if c.matches(sc.sel())]
        ok = bool(matched)
        checks.append(Check(
            f"scaling[{i}]/workload-exists", ok,
            f"{len(matched)} component(s) match {sc.sel()}" if ok
            else f"no component matches selector {sc.sel()} (unenforceable)"))
        sane = (sc.min_engines >= 0
                and (sc.max_engines is None
                     or sc.min_engines <= sc.max_engines))
        checks.append(Check(
            f"scaling[{i}]/bounds-sane", sane,
            f"min={sc.min_engines} max={sc.max_engines}" if sane
            else f"inconsistent bounds min={sc.min_engines} "
                 f"max={sc.max_engines}"))

    # ---- service-level checks (runtime latency targets) ----
    for i, lc in enumerate(intent.service):
        matched = [c for c in components if c.matches(lc.sel())]
        ok = bool(matched)
        checks.append(Check(
            f"service[{i}]/workload-exists", ok,
            f"{len(matched)} component(s) match {lc.sel()}" if ok
            else f"no component matches selector {lc.sel()} (unenforceable)"))
        sane = ((lc.max_ttft_s is None or lc.max_ttft_s > 0)
                and (lc.max_tpot_s is None or lc.max_tpot_s > 0)
                and not (lc.max_ttft_s is None and lc.max_tpot_s is None))
        checks.append(Check(
            f"service[{i}]/targets-sane", sane,
            f"ttft<={lc.max_ttft_s} tpot<={lc.max_tpot_s}" if sane
            else f"degenerate service-level targets ttft={lc.max_ttft_s} "
                 f"tpot={lc.max_tpot_s}"))

    if not checks:
        checks.append(Check("no-constraints", False,
                            "intent produced no enforceable constraints"))
    return ValidationReport(intent.text, checks, time.time() - t0)


def check_hlo_axes(hlo_text: str, forbidden_axes: Sequence[str],
                   mesh_shape: Sequence[int], axis_names: Sequence[str]
                   ) -> Tuple[bool, str]:
    """No collective in the compiled module may cross a forbidden axis."""
    model = hlo_cost.HloCostModel(hlo_text)
    totals = model.cost()
    offenders = []
    for coll, _mult in totals.collectives:
        axes = axes_crossed(coll.groups, coll.pairs, mesh_shape, axis_names)
        bad = set(axes) & set(forbidden_axes)
        if bad:
            offenders.append((coll.kind, sorted(bad)))
    if offenders:
        kinds = ", ".join(f"{k} crosses {a}" for k, a in offenders[:5])
        return False, f"{len(offenders)} collectives cross forbidden axes: {kinds}"
    return True, (f"{len(totals.collectives)} collectives checked, none cross "
                  f"{list(forbidden_axes)}")
