"""Sharded checkpointing with elastic restore.

Format: one .npz of flattened leaves + a JSON manifest (treedef, shapes,
dtypes, step). `load_checkpoint` places leaves under *target* shardings, so
restore works onto a different mesh / plan than the one that saved — the
elastic-scaling path (lose a pod, restore onto the survivor mesh and keep
going). Writes are atomic (tmp + rename) and retained with a configurable
history for failure rollback.
"""
from __future__ import annotations

import json
import os
import tempfile
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _flatten_with_paths(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def save_checkpoint(ckpt_dir: str | Path, step: int, tree: PyTree,
                    *, keep: int = 3) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    names, leaves, _ = _flatten_with_paths(tree)

    def to_np(leaf):
        arr = np.asarray(leaf)
        # np.savez cannot round-trip ml_dtypes (bfloat16 etc.) — store as
        # fp32 and cast back on restore
        if arr.dtype.kind == "V" or arr.dtype.name == "bfloat16":
            arr = np.asarray(jax.numpy.asarray(leaf).astype("float32"))
        return arr

    arrays = {f"leaf_{i}": to_np(leaf) for i, leaf in enumerate(leaves)}

    final = ckpt_dir / f"step_{step:08d}"
    tmp = Path(tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_"))
    np.savez(tmp / "leaves.npz", **arrays)
    manifest = {
        "step": step,
        "time": time.time(),
        "names": names,
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "shapes": [list(np.asarray(l).shape) for l in leaves],
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        # already saved (restart raced) — keep existing
        for f in tmp.iterdir():
            f.unlink()
        tmp.rmdir()
        return final
    os.rename(tmp, final)

    # retention
    ckpts = sorted(p for p in ckpt_dir.iterdir() if p.name.startswith("step_"))
    for old in ckpts[:-keep]:
        for f in old.iterdir():
            f.unlink()
        old.rmdir()
    return final


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in ckpt_dir.iterdir()
             if p.name.startswith("step_")]
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str | Path, like: PyTree, *,
                    step: Optional[int] = None,
                    shardings: Optional[PyTree] = None
                    ) -> Tuple[int, PyTree]:
    """Restore into the structure of `like`; leaves placed under `shardings`
    (elastic restore: any mesh works)."""
    ckpt_dir = Path(ckpt_dir)
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    data = np.load(d / "leaves.npz")
    manifest = json.loads((d / "manifest.json").read_text())

    names_like, leaves_like, treedef = _flatten_with_paths(like)
    by_name = dict(zip(manifest["names"],
                       [data[f"leaf_{i}"] for i in range(len(manifest["names"]))]))
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves_like))

    out = []
    for name, leaf, sh in zip(names_like, leaves_like, shard_leaves):
        if name not in by_name:
            raise KeyError(f"checkpoint missing leaf {name}")
        target_dtype = jax.numpy.asarray(leaf).dtype
        arr = jax.numpy.asarray(by_name[name]).astype(target_dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else arr)
    return step, jax.tree_util.tree_unflatten(treedef, out)
