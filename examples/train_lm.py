"""End-to-end training driver: data pipeline -> sharded train step ->
checkpoint/restart -> straggler monitoring, with a simulated mid-run
failure and automatic recovery.

    PYTHONPATH=src python examples/train_lm.py --steps 120
    PYTHONPATH=src python examples/train_lm.py --arch mamba2-370m --steps 50

Default is a CPU-sized model; pass --full-width for the ~100M-parameter
variant (slow on CPU — sized for a real host).
"""
import argparse
import dataclasses
import tempfile

import jax

from repro.configs import get_reduced_config
from repro.data import SyntheticLM
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.optim import AdamW, warmup_cosine
from repro.runtime import TrainRunner


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-4b")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a node failure at this step")
    ap.add_argument("--full-width", action="store_true",
                    help="~100M-parameter config (slow on CPU)")
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch)
    if args.full_width:
        cfg = dataclasses.replace(
            cfg, num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
            head_dim=64, d_ff=3072, vocab_size=32_768, max_seq_len=2048)
    print(f"arch={cfg.name} params≈{cfg.param_count()/1e6:.1f}M")

    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    opt = AdamW(lr=warmup_cosine(3e-4, 20, args.steps))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, opt))
    ds = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=0)

    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    runner = TrainRunner(step_fn=step_fn, params=params, opt_state=opt_state,
                         dataset=ds, ckpt_dir=ckpt_dir, ckpt_every=20,
                         mitigation_hook=lambda rep: print(
                             f"  [straggler] step {rep.step}: "
                             f"{rep.slowdown:.1f}x slower"))

    fail_at = args.fail_at if args.fail_at is not None else args.steps // 2
    try:
        out = runner.run(args.steps, fail_at=fail_at)
    except RuntimeError as e:
        print(f"!! {e} — recovering from {ckpt_dir}")
        out = runner.recover_and_run(args.steps)

    print(f"done: steps={out['steps']} final_loss={out['final_loss']:.4f} "
          f"restarts={out['restarts']} stragglers={out['stragglers']}")
    ls = runner.losses
    print(f"loss: first5={sum(ls[:5])/5:.4f} last5={sum(ls[-5:])/5:.4f}")


if __name__ == "__main__":
    main()
