"""Quickstart: natural-language privacy intent -> enforced fabric config.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's full control loop on the two-pod fabric model: interpret,
compile (placement + routing), fail-closed validation, apply; then shows a
deliberately unenforceable intent being rejected.
"""
import json

from repro.core import Orchestrator

orch = Orchestrator()

INTENTS = [
    "Ensure all personal health data remains within the European Union.",
    "Traffic from host 2 to host 4 must traverse switch s8 and avoid "
    "huawei switches.",
    "Place phi workloads on eu nodes and ensure their traffic avoids "
    "untrusted switches.",
    # unenforceable: no financial workload exists -> must fail closed
    "Prohibit financial database service deployment in the cloud zone.",
]

for text in INTENTS:
    print("=" * 72)
    print("INTENT:", text)
    r = orch.submit(text)
    print("  domain      :", r.policy.intent.domain,
          "/", r.policy.intent.complexity)
    print("  validator   :", r.report.summary())
    for c in r.report.checks:
        print(f"    [{'ok' if c.passed else 'XX'}] {c.name}: {c.detail[:80]}")
    print("  applied     :", r.applied)
    print("  tokens      :", r.prompt_tokens + r.completion_tokens,
          " latency: %.1f ms" % (r.total_s * 1e3))
    if r.applied and r.policy.manifests:
        print("  manifest[0] :", json.dumps(r.policy.manifests[0])[:110])
    if r.applied and r.policy.flow_rules:
        print("  flow_rule[0]:", json.dumps(r.policy.flow_rules[0])[:110])

print("=" * 72)
print("final placement:", orch.state.placement)
print("installed flows:", len(orch.state.flow_rules), "rules over",
      len(orch.state.flows), "paths")
