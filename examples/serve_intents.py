"""Intent-driven serving with online reconfiguration (the paper's scenario
on the serving fabric, evaluated on downtime / TTFT / TPOT).

    PYTHONPATH=src python examples/serve_intents.py

1. start a continuous-batching engine for a small MoE model;
2. serve a first wave of mixed phi/general requests;
3. submit the privacy intent "Phi traffic must remain inside the pod" —
   the orchestrator compiles + validates it fail-closed;
4. hot-swap the engine onto the restricted plan (ReconfigEngine) and keep
   serving; report downtime and before/after TTFT/TPOT.
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_reduced_config
from repro.core import Orchestrator, ReconfigEngine
from repro.models import build_model
from repro.serving import Request, ServingEngine


def load(engine, cfg, rng, n, base, labels):
    for rid in range(n):
        engine.submit(Request(
            base + rid,
            rng.integers(2, cfg.vocab_size, size=8).astype(np.int32),
            max_new_tokens=8, labels=labels))


def main() -> None:
    cfg = dataclasses.replace(get_reduced_config("qwen2-moe-a2.7b"),
                              param_dtype="float32", activ_dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, n_slots=4, s_max=48)
    rng = np.random.default_rng(0)

    print("== wave 1: mixed tenants, default plan ==")
    load(engine, cfg, rng, 4, 0, {"data-type": "phi"})
    load(engine, cfg, rng, 4, 10, {"data-type": "general"})
    engine.run()
    before = engine.metrics()
    print("  ", before)

    print("== intent arrives ==")
    orch = Orchestrator()
    res = orch.submit("Phi traffic must remain inside the pod and avoid "
                      "untrusted switches.")
    print("   validator:", res.report.summary())
    assert res.success
    plan = next(v for k, v in orch.state.plans.items() if "phi" in k)
    print("   restricted plan:", plan)

    print("== hot swap (compile-ahead + blocking migrate) ==")
    rc = ReconfigEngine(engine)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    report = rc.reconfigure(new_shardings={
        "params": jax.tree.map(lambda _: repl, engine.params),
        "cache": jax.tree.map(lambda _: repl, engine.cache)})
    print("  ", report.summary())

    print("== wave 2: serving continues under the restricted plan ==")
    engine.done.clear()
    load(engine, cfg, rng, 8, 100, {"data-type": "phi"})
    engine.run()
    rc.finalize_metrics(report)
    after = engine.metrics()
    print("  ", after)

    print("== summary ==")
    print(f"  downtime           : {report.downtime_s*1e3:.1f} ms")
    print(f"  TTFT before/after  : {before['ttft_mean_s']:.3f} / "
          f"{after['ttft_mean_s']:.3f} s")
    print(f"  TPOT before/after  : {before['tpot_mean_s']:.3f} / "
          f"{after['tpot_mean_s']:.3f} s")


if __name__ == "__main__":
    main()
