"""Intent-driven serving with online reconfiguration (the paper's scenario
on the serving fabric, evaluated on downtime / TTFT / TPOT).

    PYTHONPATH=src python examples/serve_intents.py

Public-API flow only (no private engine attributes, no plan fishing):

1. register a continuous-batching engine with a `ServingCluster`;
2. serve a first wave of mixed phi/general requests through the cluster;
3. submit the privacy intent "Phi traffic must remain inside the pod" with
   ``apply_to=cluster`` — the orchestrator compiles + validates it
   fail-closed, then the cluster AOT-compiles the new executables in the
   PREPARE phase and hot-swaps every affected engine (blocking window
   contains migration only, never compilation);
4. keep serving phi traffic under the restricted plan; the DowntimeReport
   finalizes its after-swap metrics automatically.
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_reduced_config
from repro.core import Orchestrator
from repro.models import build_model
from repro.serving import Request, RoutingError, ServingCluster, ServingEngine
from repro.sharding import default_plan


def load(cluster, cfg, rng, n, base, labels):
    for rid in range(n):
        cluster.submit(Request(
            base + rid,
            rng.integers(2, cfg.vocab_size, size=8).astype(np.int32),
            max_new_tokens=8, labels=labels))


def main() -> None:
    cfg = dataclasses.replace(get_reduced_config("qwen2-moe-a2.7b"),
                              param_dtype="float32", activ_dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, n_slots=4, s_max=48)

    cluster = ServingCluster()
    cluster.register("edge0", engine, plan=default_plan())
    rng = np.random.default_rng(0)

    print("== wave 1: mixed tenants, default plan ==")
    load(cluster, cfg, rng, 4, 0, {"data-type": "phi"})
    load(cluster, cfg, rng, 4, 10, {"data-type": "general"})
    cluster.run()
    before = cluster.metrics("edge0")
    print("  ", before)

    print("== intent arrives: validate + reconfigure through the cluster ==")
    orch = Orchestrator()
    res = orch.submit("Phi traffic must remain inside the pod and avoid "
                      "untrusted switches.", apply_to=cluster)
    print("   validator:", res.report.summary())
    assert res.success
    report = res.reports["edge0"]
    print("   restricted plan:", cluster.engine("edge0").plan)
    print("   route constraints:", cluster.route_constraints())
    print("  ", report.summary())
    assert report.compiled_in_prepare > 0, "PREPARE must AOT-compile"

    print("== wave 2: serving continues under the restricted plan ==")
    load(cluster, cfg, rng, 8, 100, {"data-type": "phi"})
    cluster.run()   # auto-finalizes report.metrics_after (post-swap window)
    after = report.metrics_after
    print("  ", after)

    print("== fail-closed routing ==")
    try:
        strict = ServingCluster()
        strict.register("noncompliant", ServingEngine(
            model, params, n_slots=2, s_max=48))
        strict.set_route_constraint(
            "phi", cluster.route_constraints()["phi"])
        strict.submit(Request(999, rng.integers(2, cfg.vocab_size, size=8)
                              .astype(np.int32), labels={"data-type": "phi"}))
    except RoutingError as e:
        print("   rejected as expected:", e)
    else:
        raise SystemExit("FAIL-OPEN: a non-compliant engine accepted phi "
                         "traffic — the routing guarantee has regressed")

    print("== summary ==")
    print(f"  prepare (AOT x{report.compiled_in_prepare})"
          f" : {report.prepare_s*1e3:.1f} ms  (serving continues)")
    print(f"  downtime           : {report.downtime_s*1e3:.1f} ms")
    print(f"  TTFT before/after  : {report.metrics_before['ttft_mean_s']:.3f}"
          f" / {after['ttft_mean_s']:.3f} s")
    print(f"  TPOT before/after  : {report.metrics_before['tpot_mean_s']:.3f}"
          f" / {after['tpot_mean_s']:.3f} s")


if __name__ == "__main__":
    main()
