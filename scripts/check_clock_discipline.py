#!/usr/bin/env python
"""Static clock-discipline check for the serving + observability layers.

The simulated-clock contract (`repro.serving.clock`) only holds when
every module that reads time does so through its swappable module-level
``time`` attribute AND is listed in ``CLOCKED_MODULE_NAMES`` so
`install_clock` actually swaps it. A raw ``time.time()`` /
``time.monotonic()`` / ``datetime.now()`` in an unregistered module is a
wall-clock leak: correct-looking at system speed, silently wrong (and
nondeterministic) in every simulated replay.

This script scans ``src/repro/serving`` and ``src/repro/obs`` for:

  * ``import time`` / ``from time import ...`` in a module NOT listed in
    ``CLOCKED_MODULE_NAMES`` (clock.py itself is exempt — it OWNS the
    real clock, aliased as ``_time``);
  * ``datetime.now`` / ``datetime.utcnow`` / ``time.time()`` style calls
    anywhere in those trees outside clock.py;
  * the migration/handoff hot path specifically: every module whose
    source participates in the first-token handoff or the batched
    migration pause (it mentions ``handoff`` or ``pause_s``) MUST be
    registered, whether or not it imports ``time`` today — a pause
    stamped off the wall clock would corrupt every simulated replay's
    downtime/SLO ledger;
  * the Watchtower layer specifically: ``repro/obs/lineage.py`` and
    ``repro/obs/alerts.py`` MUST exist and be registered — attribution
    timestamps and alert/burn-rate timestamps compared against
    event-stream timestamps from a DIFFERENT clock would silently
    corrupt detection latencies and conservation checks.

Exit status 1 (CI fails) on any violation. Wired into scripts/ci.sh and
``make lint``.
"""
from __future__ import annotations

import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
SRC = REPO / "src"
SCANNED_DIRS = ("repro/serving", "repro/obs")
EXEMPT = "repro/serving/clock.py"     # owns the real clock (as _time)

IMPORT_RE = re.compile(r"^\s*(import\s+time\b|from\s+time\s+import\b)",
                       re.MULTILINE)
DATETIME_RE = re.compile(
    r"\bdatetime\.(?:now|utcnow|today)\s*\(|\bdatetime\.datetime\b")
# modules on the migration/handoff pause-stamping hot path: anything
# mentioning the first-token handoff or a migration pause stamp
HANDOFF_RE = re.compile(r"\bhandoff\b|\bpause_s\b")
#: modules that must BOTH exist and be clock-registered: the Watchtower
#: layer stamps attribution/alert times that are compared against
#: event-stream timestamps, so a missing registration (or a renamed
#: file silently dropping out of the scan) is a correctness bug
REQUIRED_CLOCKED = (
    "repro/obs/events.py",
    "repro/obs/lineage.py",
    "repro/obs/alerts.py",
)


def clocked_modules() -> set:
    sys.path.insert(0, str(SRC))
    from repro.serving.clock import CLOCKED_MODULE_NAMES
    return set(CLOCKED_MODULE_NAMES)


def module_name(path: pathlib.Path) -> str:
    rel = path.relative_to(SRC).with_suffix("")
    return ".".join(rel.parts)


def main() -> int:
    registered = clocked_modules()
    violations = []
    for rel in REQUIRED_CLOCKED:
        path = SRC / rel
        if not path.exists():
            violations.append(
                f"{rel}: required clock-disciplined module is missing "
                "(the Watchtower layer depends on it)")
            continue
        mod = module_name(path)
        if mod not in registered:
            violations.append(
                f"{rel}: {mod!r} must be registered in "
                "repro.serving.clock.CLOCKED_MODULE_NAMES — its "
                "timestamps are compared against event-stream "
                "timestamps, so an unswapped clock silently corrupts "
                "attribution and alert latencies in simulated replays")
    for d in SCANNED_DIRS:
        for path in sorted((SRC / d).rglob("*.py")):
            rel = path.relative_to(SRC).as_posix()
            if rel == EXEMPT:
                continue
            text = path.read_text()
            if DATETIME_RE.search(text):
                violations.append(
                    f"{rel}: datetime-based wall-clock read — route it "
                    "through the module 'time' attribute and register the "
                    "module in CLOCKED_MODULE_NAMES")
            if IMPORT_RE.search(text):
                mod = module_name(path)
                if mod not in registered:
                    violations.append(
                        f"{rel}: imports 'time' but {mod!r} is not in "
                        "repro.serving.clock.CLOCKED_MODULE_NAMES — "
                        "install_clock would never swap it, so simulated "
                        "replays would silently read the wall clock")
            # serving modules on the migration/handoff pause path must be
            # registered even before they grow a 'time' import: their
            # pause stamps feed the SLO ledger's downtime accounting
            if d == "repro/serving" and HANDOFF_RE.search(text):
                mod = module_name(path)
                if mod not in registered:
                    violations.append(
                        f"{rel}: participates in the migration/handoff "
                        f"pause path but {mod!r} is not in "
                        "CLOCKED_MODULE_NAMES — its pause stamps would "
                        "read the wall clock in simulated replays")
    if violations:
        print("clock-discipline violations:")
        for v in violations:
            print(f"  {v}")
        return 1
    print(f"clock discipline OK: every time-importing module under "
          f"{' + '.join(SCANNED_DIRS)} is registered in "
          f"CLOCKED_MODULE_NAMES ({len(registered)} modules)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
