#!/usr/bin/env bash
# Tier-1 verification + the intent-driven reconfiguration path + docs.
# Run from the repo root:  bash scripts/ci.sh   (or: make ci)
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== lint: clock discipline (no wall-clock reads off the registry) =="
python scripts/check_clock_discipline.py

echo "== tier-1: test suite =="
python -m pytest -x -q

echo "== reconfiguration path: serve_intents example (reduced config) =="
PYTHONPATH=src python examples/serve_intents.py

echo "== docs: execute the embedded examples (they must not rot) =="
python scripts/run_doc_examples.py

echo "== serving benchmarks: perf-trajectory artifacts (BENCH_*.json) =="
echo "==   --check gates curated metrics against the committed baselines =="
PYTHONPATH=src:. python benchmarks/run.py --check --only reconfig migration elastic overlap planner paged scale obs disagg watch

echo "CI OK"
