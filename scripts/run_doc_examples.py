#!/usr/bin/env python
"""Execute the ```python code blocks embedded in docs/*.md so the
documented examples can't rot.

For each markdown file, every fenced ``python`` block is extracted and
concatenated IN ORDER into one script (the docs are written as a single
narrative — later blocks may use names defined earlier), then executed in
a subprocess with ``PYTHONPATH=src``. Blocks fenced as anything other
than ``python`` (e.g. ``text``) and blocks whose first line contains
``# doc-only`` are skipped.

    python scripts/run_doc_examples.py            # all docs/*.md
    python scripts/run_doc_examples.py docs/architecture.md
"""
from __future__ import annotations

import os
import pathlib
import re
import subprocess
import sys
import tempfile

REPO = pathlib.Path(__file__).resolve().parent.parent
FENCE = re.compile(r"^```python[ \t]*\n(.*?)^```[ \t]*$",
                   re.MULTILINE | re.DOTALL)


def extract_blocks(md_path: pathlib.Path) -> list:
    blocks = FENCE.findall(md_path.read_text())
    runnable = []
    for block in blocks:
        first = block.lstrip().splitlines()[0] if block.strip() else ""
        if "# doc-only" in first:
            continue
        runnable.append(block)
    return runnable


def run_doc(md_path: pathlib.Path) -> int:
    blocks = extract_blocks(md_path)
    if not blocks:
        print(f"-- {md_path.relative_to(REPO)}: no runnable blocks")
        return 0
    header = (f"# auto-extracted from {md_path.name} by "
              "scripts/run_doc_examples.py\n")
    source = header + "\n\n".join(blocks) + "\n"
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    with tempfile.NamedTemporaryFile(
            "w", suffix=f"_{md_path.stem}.py", delete=False) as f:
        f.write(source)
        script = f.name
    print(f"== {md_path.relative_to(REPO)}: "
          f"{len(blocks)} block(s) ==", flush=True)
    proc = subprocess.run([sys.executable, script], env=env, cwd=str(REPO))
    if proc.returncode != 0:
        # keep the extracted script on failure so it can be debugged
        print(f"FAILED: {md_path.relative_to(REPO)} "
              f"(extracted script kept at {script})")
        return proc.returncode
    os.unlink(script)
    print(f"OK: {md_path.relative_to(REPO)}")
    return 0


def main(argv: list) -> int:
    targets = ([pathlib.Path(a).resolve() for a in argv]
               or sorted((REPO / "docs").glob("*.md")))
    rc = 0
    for md in targets:
        rc = run_doc(md) or rc
    return rc


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
